//! KVStore — data synchronization over devices and machines
//! (paper §2.3, implementation §3.3).
//!
//! Two primitives: **push** a gradient for a key, **pull** the current
//! weight.  A user-defined *updater* (usually an [`Optimizer`]) merges
//! pushed values into the stored weight.  Consistency is controlled by a
//! [`Consistency`] model: `Sequential` pulls observe every push the caller
//! issued before; `Eventual` pulls return immediately with a possibly
//! stale snapshot (paper: *"intra- is sequential and inter- is
//! eventual"*).
//!
//! Two implementations:
//!
//! * [`LocalKVStore`] — the level-1 server: aggregates pushes from the
//!   devices (worker threads) of one machine, applies the updater once
//!   per round.  Push/pull are engine operations, so they schedule
//!   seamlessly against compute (§3.3: *"we use the engine to schedule
//!   the KVStore operations"*).
//! * [`DistKVStore`](dist::DistKVStore) — the two-level structure: a
//!   level-1 local aggregator whose merged gradient is forwarded to the
//!   level-2 TCP [server](server), cutting inter-machine bandwidth by the
//!   per-machine device count.

pub mod dist;
pub mod fault;
pub mod server;
pub mod shard;
pub mod wire;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Lock a mutex, recovering from poisoning: a panicking peer thread must
/// not cascade into the server/client that shares its state (robustness
/// over strictness — the guarded data is plain counters and buffers).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Condvar wait with the same poison recovery as [`lock`].
pub(crate) fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|p| p.into_inner())
}

use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::ndarray::{pool, NDArray};
use crate::optimizer::Optimizer;

/// Consistency model for pulls (paper §2.3: *"model divergence is
/// controlled via consistency model"*).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Consistency {
    /// A pull observes all pushes issued before it by this worker.
    Sequential,
    /// The bounded-delay model the paper's §2.3 footnote sketches,
    /// sitting between `Sequential` and `Eventual`: a pull observes a
    /// **committed snapshot at most `k` rounds older** than the newest
    /// pushed round — it blocks (backpressure) until the snapshot
    /// catches up to `push_round - k`.  `BoundedDelay(0)` has
    /// `Sequential` freshness; large `k` approaches `Eventual`.
    BoundedDelay(u64),
    /// A pull may return a stale snapshot (no blocking, no bound).
    Eventual,
}

/// The push/pull interface shared by local and distributed stores.
pub trait KVStore: Send + Sync {
    /// Register a key with its initial weight value.
    fn init(&self, key: &str, value: &NDArray) -> Result<()>;

    /// Push a gradient contribution for `key` from device `device`.
    fn push(&self, key: &str, grad: &NDArray, device: usize) -> Result<()>;

    /// Deliver one device's gradient for `key` **by value** into the
    /// store's device-sliced round staging (slot `part`, one slot per
    /// device of the round).  Unlike [`KVStore::push`] — whose
    /// accumulation order is arrival order — staged parts are reduced in
    /// **part-index order** once the round is complete, so the merged
    /// gradient is bitwise identical however deliveries interleave.
    ///
    /// Caller contract: `grad` holds this round's final gradient value.
    /// The data-parallel trainer calls this from an executor grad-ready
    /// hook (mid-backward, the paper's §5 communication/compute overlap)
    /// or from an engine op reading the gradient.  A round must not mix
    /// `push` and `push_part`, and each part may be delivered at most
    /// once per round — a fit aborted mid-round leaves its staged parts
    /// behind, so a store must not be reused across a failed fit.
    ///
    /// Required (no default): `Module::fit` and the trainer deliver
    /// every gradient through this path, so an implementation without it
    /// would silently never train.
    fn push_part(&self, key: &str, grad: &[f32], part: usize) -> Result<()>;

    /// Pull the current weight for `key` into `out`.
    fn pull(&self, key: &str, out: &NDArray, device: usize) -> Result<()>;

    /// Block until all outstanding store operations have been applied.
    fn flush(&self);

    /// The number of devices pushing per round.
    fn num_devices(&self) -> usize;

    /// The consistency model in effect.
    fn consistency(&self) -> Consistency;

    /// Export the store's recoverable state — master weights, per-key
    /// round versions, updater state — into a
    /// [`TrainState`](crate::io::checkpoint::TrainState) (trainer-level
    /// fields are left default for the caller to fill).  Default: not
    /// supported; [`LocalKVStore`] implements it.  [`DistKVStore`]
    /// (dist) keeps the default — the level-2 server owns the master
    /// weights there, and crash recovery runs through the lease
    /// protocol instead.
    fn export_train_state(&self) -> Result<crate::io::checkpoint::TrainState> {
        Err(Error::kv("this store does not support train-state export"))
    }

    /// Restore weights, versions, and updater state previously produced
    /// by [`export_train_state`](KVStore::export_train_state),
    /// replacing any existing keys.  Default: not supported.
    fn restore_train_state(&self, st: &crate::io::checkpoint::TrainState) -> Result<()> {
        let _ = st;
        Err(Error::kv("this store does not support train-state restore"))
    }
}

/// Device-sliced round staging shared by [`LocalKVStore`] and
/// [`DistKVStore`](dist::DistKVStore): one pooled-buffer slot per part,
/// delivery validation, and round-completion detection.  Parts are
/// handed back in **part-index order**; what consumes the completed
/// round (a local reduce into the accum buffer vs an aggregated wire
/// message) stays store-specific.
pub(crate) struct PartStage {
    slots: Vec<Option<Box<[f32]>>>,
    filled: usize,
}

impl PartStage {
    pub(crate) fn new(parts: usize) -> PartStage {
        PartStage { slots: (0..parts).map(|_| None).collect(), filled: 0 }
    }

    /// Whether the current round has at least one staged part.
    pub(crate) fn in_progress(&self) -> bool {
        self.filled > 0
    }

    /// Stage `grad` into `part`'s slot.  On the round's last delivery
    /// all parts are returned in part-index order and the slots are
    /// emptied immediately — a queued consumer can never race the next
    /// round's deliveries.
    pub(crate) fn stage(
        &mut self,
        key: &str,
        grad: &[f32],
        part: usize,
        expect_len: usize,
    ) -> Result<Option<Vec<Box<[f32]>>>> {
        if part >= self.slots.len() {
            return Err(Error::kv(format!(
                "key '{key}': part {part} out of range ({} per round)",
                self.slots.len()
            )));
        }
        if grad.len() != expect_len {
            return Err(Error::kv(format!(
                "key '{key}': push_part len {} != weight size {expect_len}",
                grad.len()
            )));
        }
        if self.slots[part].is_some() {
            return Err(Error::kv(format!(
                "key '{key}': part {part} already delivered this round"
            )));
        }
        let mut buf = pool::global().acquire_uninit(grad.len());
        buf.copy_from_slice(grad);
        self.slots[part] = Some(buf);
        self.filled += 1;
        if self.filled == self.slots.len() {
            self.filled = 0;
            Ok(Some(self.slots.iter_mut().map(|s| s.take().expect("full round")).collect()))
        } else {
            Ok(None)
        }
    }
}

/// A committed parameter snapshot: the value the weight held after some
/// completed round, shared with snapshot-reading paths (eventual and
/// bounded-delay pulls, live serving).  Commits happen inside engine ops
/// ordered after the round's updater, so a reader never observes a
/// half-written ("torn") buffer — it sees exactly the bytes of one
/// committed round.
pub(crate) struct SnapCell {
    data: Mutex<Vec<f32>>,
    /// The round (key version) the committed bytes correspond to.
    round: AtomicU64,
    cv: Condvar,
}

impl SnapCell {
    fn new(init: Vec<f32>) -> SnapCell {
        SnapCell { data: Mutex::new(init), round: AtomicU64::new(0), cv: Condvar::new() }
    }

    /// Commit `w` as the snapshot of `round`.  Snapshot ops all read the
    /// weight var, so the engine serializes them between updater writes
    /// and they arrive in round order; the monotonic guard is belt and
    /// braces.
    fn commit(&self, w: &[f32], round: u64) {
        let mut d = lock(&self.data);
        if round <= self.round.load(Ordering::Relaxed) && round != 0 {
            return;
        }
        d.clear();
        d.extend_from_slice(w);
        self.round.store(round, Ordering::Release);
        self.cv.notify_all();
    }

    fn round(&self) -> u64 {
        self.round.load(Ordering::Acquire)
    }

    /// Block the calling thread until the committed snapshot is at least
    /// `target` rounds new — the bounded-delay backpressure point.
    fn wait_round(&self, target: u64) {
        let mut d = lock(&self.data);
        while self.round.load(Ordering::Acquire) < target {
            d = wait(&self.cv, d);
        }
    }

    /// A copy of the committed bytes plus the round they belong to, read
    /// atomically (the pair can never mix two rounds).  The buffer is
    /// leased from the storage pool — the consuming engine op releases
    /// it — so steady-state bounded-delay pulls and live refreshes
    /// allocate nothing after warmup (the PR 3 hot-loop contract).
    fn take_committed(&self) -> (Box<[f32]>, u64) {
        let d = lock(&self.data);
        let mut buf = pool::global().acquire_uninit(d.len());
        buf.copy_from_slice(&d);
        (buf, self.round.load(Ordering::Relaxed))
    }

    /// Lock the committed bytes for in-place reading (engine-op side).
    fn read(&self) -> std::sync::MutexGuard<'_, Vec<f32>> {
        lock(&self.data)
    }
}

/// Pull-path statistics (see [`LocalKVStore::pull_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PullStats {
    /// Pulls that scheduled a copy.
    pub copies: u64,
    /// Pulls answered from the device cache (version unchanged).
    pub skips: u64,
    /// Snapshot age (rounds behind the newest pushed round) observed by
    /// the most recent snapshot-serving pull.
    pub last_snap_age: u64,
    /// Largest snapshot age any snapshot-serving pull observed — the
    /// staleness a bounded-delay test asserts against its `k`.
    pub max_snap_age: u64,
}

struct KeyState {
    weight: NDArray,
    /// Merged-gradient buffer the updater consumes.
    accum: NDArray,
    /// Devices that have pushed this round (legacy arrival-order path).
    pushed: usize,
    /// Device-sliced staging for the current round (`push_part` path).
    stage: PartStage,
    /// Updates scheduled so far — the version stamp behind skip-on-pull.
    version: u64,
    /// device -> (version, out-var id) of its last sequential pull.
    pulled: HashMap<usize, (u64, u64)>,
    /// device -> (snapshot round, out-var id) of its last snapshot pull.
    pulled_snap: HashMap<usize, (u64, u64)>,
    /// Committed snapshot for eventual / bounded-delay / live pulls.
    snap: Arc<SnapCell>,
    /// Highest round for which a snapshot op has been *scheduled* (the
    /// commit itself runs later, as an engine op).
    snap_sched: u64,
}

/// Level-1 (intra-machine) key-value store over the dependency engine.
pub struct LocalKVStore {
    engine: EngineRef,
    num_devices: usize,
    consistency: Consistency,
    updater: Arc<dyn Optimizer>,
    keys: Mutex<HashMap<String, KeyState>>,
    pull_copies: AtomicU64,
    pull_skips: AtomicU64,
    /// Commit a snapshot every N completed rounds (default 1).
    snapshot_cadence: AtomicU64,
    snap_age_last: AtomicU64,
    snap_age_max: AtomicU64,
}

impl LocalKVStore {
    /// Create a store aggregating `num_devices` pushes per round and
    /// applying `updater` to merge them.
    pub fn new(
        engine: EngineRef,
        num_devices: usize,
        updater: Arc<dyn Optimizer>,
        consistency: Consistency,
    ) -> Self {
        LocalKVStore {
            engine,
            num_devices: num_devices.max(1),
            consistency,
            updater,
            keys: Mutex::new(HashMap::new()),
            pull_copies: AtomicU64::new(0),
            pull_skips: AtomicU64::new(0),
            snapshot_cadence: AtomicU64::new(1),
            snap_age_last: AtomicU64::new(0),
            snap_age_max: AtomicU64::new(0),
        }
    }

    /// Commit a snapshot every `rounds` completed rounds instead of every
    /// round (the default).  A coarser cadence makes eventual pulls
    /// staler but cheaper; bounded-delay pulls stay correct — a pull
    /// whose staleness target outruns the cadence schedules a demand
    /// snapshot itself.
    pub fn snapshot_every(&self, rounds: u64) {
        self.snapshot_cadence.store(rounds.max(1), Ordering::Relaxed);
    }

    /// Pull-path statistics: copies vs cache skips, plus the snapshot
    /// age (rounds behind the newest push round) the snapshot-serving
    /// pulls actually observed — what a bounded-delay staleness test
    /// asserts never exceeded its `k`.
    pub fn pull_stats(&self) -> PullStats {
        PullStats {
            copies: self.pull_copies.load(Ordering::Relaxed),
            skips: self.pull_skips.load(Ordering::Relaxed),
            last_snap_age: self.snap_age_last.load(Ordering::Relaxed),
            max_snap_age: self.snap_age_max.load(Ordering::Relaxed),
        }
    }

    /// The round (version) of the currently committed snapshot for `key`.
    pub fn snapshot_round(&self, key: &str) -> Result<u64> {
        let keys = lock(&self.keys);
        let st = keys.get(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        Ok(st.snap.round())
    }

    /// Element count of `key`'s weight (live-serving attach validation).
    pub fn value_len(&self, key: &str) -> Result<usize> {
        let keys = lock(&self.keys);
        let st = keys.get(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        Ok(st.weight.size())
    }

    /// Schedule one engine op copying `out` from the latest **committed**
    /// snapshot, whatever consistency mode the store runs — the live
    /// serving path.  The bytes are captured on the caller thread under
    /// the snapshot lock, so the destination receives exactly one
    /// committed round (never a torn mix), and the engine write grant on
    /// `out` orders the refresh against any in-flight forward reading it.
    /// Returns the round captured.
    pub fn pull_committed(&self, key: &str, out: &NDArray) -> Result<u64> {
        let snap = {
            let keys = lock(&self.keys);
            let st =
                keys.get(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
            Arc::clone(&st.snap)
        };
        let (data, round) = snap.take_committed();
        if data.len() != out.size() {
            let n = data.len();
            pool::global().release(data);
            return Err(Error::kv(format!(
                "pull_committed '{key}': out size {} != weight size {n}",
                out.size()
            )));
        }
        let os = out.storage();
        self.engine.push(
            "kv.pull_live",
            vec![],
            vec![out.var()],
            Box::new(move || {
                unsafe { os.slice_mut() }.copy_from_slice(&data);
                pool::global().release(data);
            }),
        );
        Ok(round)
    }

    fn record_snap_age(&self, age: u64) {
        self.snap_age_last.store(age, Ordering::Relaxed);
        self.snap_age_max.fetch_max(age, Ordering::Relaxed);
    }

    /// Schedule a snapshot of the weight as of `st.version`.  The op
    /// *reads* the weight var: the engine orders it after this round's
    /// updater and before the next round's (WAR), so commits land in
    /// round order carrying exactly the post-round bytes.
    fn schedule_snapshot(&self, st: &mut KeyState) {
        let round = st.version;
        st.snap_sched = round;
        let snap = Arc::clone(&st.snap);
        let ws = st.weight.storage();
        self.engine.push(
            "kv.snapshot",
            vec![st.weight.var()],
            vec![],
            Box::new(move || {
                let w = unsafe { ws.slice() };
                snap.commit(w, round);
            }),
        );
    }

    /// Export master weights, versions, and updater state for
    /// checkpointing (see [`KVStore::export_train_state`]).  Waits for
    /// in-flight engine ops first so the exported bytes are exactly the
    /// state of the last completed round.
    fn export_state_inner(&self) -> Result<crate::io::checkpoint::TrainState> {
        self.engine.wait_all();
        let keys = lock(&self.keys);
        let mut names: Vec<&String> = keys.keys().collect();
        names.sort();
        let mut ts = crate::io::checkpoint::TrainState::default();
        for name in names {
            let ks = &keys[name.as_str()];
            ts.params.push((name.clone(), ks.weight.shape().to_vec(), ks.weight.to_vec()));
            ts.versions.push((name.clone(), ks.version));
        }
        ts.updater = self.updater.export_state();
        Ok(ts)
    }

    /// Rebuild key state from a checkpoint (see
    /// [`KVStore::restore_train_state`]).
    fn restore_state_inner(&self, ts: &crate::io::checkpoint::TrainState) -> Result<()> {
        let versions: HashMap<&str, u64> =
            ts.versions.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        {
            let mut keys = lock(&self.keys);
            for (name, shape, data) in &ts.params {
                let n: usize = shape.iter().product();
                if n != data.len() {
                    return Err(Error::kv(format!(
                        "restore '{name}': shape {shape:?} holds {n} values, data has {}",
                        data.len()
                    )));
                }
                let version = *versions.get(name.as_str()).unwrap_or(&0);
                let weight = NDArray::from_vec_on(shape, data.clone(), self.engine.clone());
                let accum = NDArray::zeros_on(shape, self.engine.clone());
                let snap = Arc::new(SnapCell::new(data.clone()));
                snap.commit(data, version);
                keys.insert(
                    name.clone(),
                    KeyState {
                        weight,
                        accum,
                        pushed: 0,
                        stage: PartStage::new(self.num_devices),
                        version,
                        pulled: HashMap::new(),
                        pulled_snap: HashMap::new(),
                        snap,
                        snap_sched: version,
                    },
                );
            }
        }
        self.updater.import_state(&ts.updater, &self.engine);
        Ok(())
    }

    /// Round complete: bump the version, run the user updater on the
    /// merged gradient, refresh the committed snapshot on cadence.
    /// Caller holds the keys lock, so the updater and snapshot ops are
    /// scheduled atomically with the round bookkeeping.
    fn commit_round(&self, key: &str, st: &mut KeyState) {
        st.version += 1;
        self.updater.update(key, &st.weight, &st.accum);
        let cadence = self.snapshot_cadence.load(Ordering::Relaxed).max(1);
        if st.version >= st.snap_sched + cadence {
            self.schedule_snapshot(st);
        }
    }
}

impl KVStore for LocalKVStore {
    fn init(&self, key: &str, value: &NDArray) -> Result<()> {
        let mut keys = lock(&self.keys);
        if keys.contains_key(key) {
            return Err(Error::kv(format!("key '{key}' already initialized")));
        }
        let weight = NDArray::zeros_on(value.shape(), self.engine.clone());
        weight.copy_from_(value);
        let accum = NDArray::zeros_on(value.shape(), self.engine.clone());
        keys.insert(
            key.to_string(),
            KeyState {
                weight,
                accum,
                pushed: 0,
                stage: PartStage::new(self.num_devices),
                version: 0,
                pulled: HashMap::new(),
                pulled_snap: HashMap::new(),
                // the init value is the committed snapshot of round 0
                snap: Arc::new(SnapCell::new(value.to_vec())),
                snap_sched: 0,
            },
        );
        Ok(())
    }

    fn push(&self, key: &str, grad: &NDArray, _device: usize) -> Result<()> {
        let mut keys = lock(&self.keys);
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        if st.stage.in_progress() {
            return Err(Error::kv(format!(
                "key '{key}': round mixes push and push_part"
            )));
        }
        if st.pushed == 0 {
            st.accum.zero_();
        }
        st.accum.add_(grad);
        st.pushed += 1;
        if st.pushed == self.num_devices {
            st.pushed = 0;
            self.commit_round(key, st);
        }
        Ok(())
    }

    fn push_part(&self, key: &str, grad: &[f32], part: usize) -> Result<()> {
        let mut keys = lock(&self.keys);
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        if st.pushed > 0 {
            return Err(Error::kv(format!(
                "key '{key}': round mixes push and push_part"
            )));
        }
        let parts = match st.stage.stage(key, grad, part, st.weight.size())? {
            None => return Ok(()),
            Some(parts) => parts,
        };
        // Round complete: reduce the parts in part order inside one
        // engine op writing the accum buffer — bitwise-fixed aggregation
        // whatever the delivery order.
        let ws = st.accum.storage();
        let n = st.weight.size();
        self.engine.push(
            "kv.reduce_parts",
            vec![],
            vec![st.accum.var()],
            Box::new(move || {
                let dst = unsafe { &mut ws.slice_mut()[..n] };
                for (i, part) in parts.into_iter().enumerate() {
                    if i == 0 {
                        dst.copy_from_slice(&part);
                    } else {
                        for (d, s) in dst.iter_mut().zip(part.iter()) {
                            *d += *s;
                        }
                    }
                    pool::global().release(part);
                }
            }),
        );
        self.commit_round(key, st);
        Ok(())
    }

    fn pull(&self, key: &str, out: &NDArray, device: usize) -> Result<()> {
        let mut keys = lock(&self.keys);
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        match self.consistency {
            Consistency::Sequential => {
                // Version-stamped pull: if this device already pulled the
                // current version into this very array — and pulls are
                // the only writer of pull targets — the copy is a no-op;
                // skip scheduling it.  The stamp pairs the version with
                // the destination var so pulling into a different array
                // always copies.
                let stamp = (st.version, out.var().id());
                if st.pulled.get(&device) == Some(&stamp) {
                    self.pull_skips.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                // Engine dependency on the weight var orders this pull
                // after every previously-scheduled update.
                out.copy_from_(&st.weight);
                st.pulled.insert(device, stamp);
                self.pull_copies.fetch_add(1, Ordering::Relaxed);
            }
            Consistency::Eventual => {
                let snap_round = st.snap.round();
                self.record_snap_age(st.version.saturating_sub(snap_round));
                let stamp = (snap_round, out.var().id());
                if st.pulled_snap.get(&device) == Some(&stamp) {
                    self.pull_skips.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                // Snapshot read: no dependency on in-flight updates.  The
                // op may observe a snapshot newer than `stamp` records —
                // that only means the next pull conservatively re-copies.
                let snap = Arc::clone(&st.snap);
                let os = out.storage();
                self.engine.push(
                    "kv.pull_eventual",
                    vec![],
                    vec![out.var()],
                    Box::new(move || {
                        let s = snap.read();
                        unsafe { os.slice_mut() }.copy_from_slice(&s);
                    }),
                );
                st.pulled_snap.insert(device, stamp);
                self.pull_copies.fetch_add(1, Ordering::Relaxed);
            }
            Consistency::BoundedDelay(k) => {
                // Staleness ceiling: serve a committed snapshot no older
                // than `version - k`.  The wait happens on the *caller*
                // thread (the trainer), which is exactly the backpressure
                // the bounded-delay model prescribes — the engine keeps
                // draining the updater/snapshot ops that unblock it.
                let target = st.version.saturating_sub(k);
                if st.snap_sched < target {
                    // Snapshot cadence lags the bound: demand one.  The
                    // op reads the weight var, so it commits the state of
                    // exactly `st.version` rounds.
                    self.schedule_snapshot(st);
                }
                let cur_round = st.snap.round();
                if cur_round >= target
                    && st.pulled_snap.get(&device) == Some(&(cur_round, out.var().id()))
                {
                    self.record_snap_age(st.version.saturating_sub(cur_round));
                    self.pull_skips.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                let snap = Arc::clone(&st.snap);
                let version = st.version;
                drop(keys);
                snap.wait_round(target);
                // Capture the committed bytes *now*, on the caller
                // thread: a later snapshot commit racing the copy op
                // could otherwise serve a round newer than the caller's
                // staleness window implies (and would break the
                // BoundedDelay(0) ≡ Sequential bitwise contract).
                let (data, observed) = snap.take_committed();
                self.record_snap_age(version.saturating_sub(observed));
                if data.len() != out.size() {
                    let n = data.len();
                    pool::global().release(data);
                    return Err(Error::kv(format!(
                        "pull '{key}': out size {} != weight size {n}",
                        out.size()
                    )));
                }
                let os = out.storage();
                self.engine.push(
                    "kv.pull_bounded",
                    vec![],
                    vec![out.var()],
                    Box::new(move || {
                        unsafe { os.slice_mut() }.copy_from_slice(&data);
                        pool::global().release(data);
                    }),
                );
                let mut keys = lock(&self.keys);
                if let Some(st) = keys.get_mut(key) {
                    st.pulled_snap.insert(device, (observed, out.var().id()));
                }
                self.pull_copies.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    fn flush(&self) {
        self.engine.wait_all();
    }

    fn num_devices(&self) -> usize {
        self.num_devices
    }

    fn consistency(&self) -> Consistency {
        self.consistency
    }

    fn export_train_state(&self) -> Result<crate::io::checkpoint::TrainState> {
        self.export_state_inner()
    }

    fn restore_train_state(&self, st: &crate::io::checkpoint::TrainState) -> Result<()> {
        self.restore_state_inner(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::optimizer::Sgd;

    fn store(devices: usize, consistency: Consistency) -> (LocalKVStore, EngineRef) {
        let engine = create(EngineKind::Threaded, 4);
        let opt = Arc::new(Sgd::new(1.0)); // lr=1 -> w -= sum(grads)
        (LocalKVStore::new(engine.clone(), devices, opt, consistency), engine)
    }

    #[test]
    fn init_push_pull_single_device() {
        let (kv, e) = store(1, Consistency::Sequential);
        let w0 = NDArray::from_vec_on(&[2], vec![1.0, 2.0], e.clone());
        kv.init("w", &w0).unwrap();
        let g = NDArray::from_vec_on(&[2], vec![0.5, 0.5], e.clone());
        kv.push("w", &g, 0).unwrap();
        let out = NDArray::zeros_on(&[2], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.5, 1.5]);
    }

    #[test]
    fn double_init_rejected() {
        let (kv, _e) = store(1, Consistency::Sequential);
        let w = NDArray::ones(&[1]);  // engine-local state untouched by init
        kv.init("w", &w).unwrap();
        assert!(kv.init("w", &w).is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let (kv, _e) = store(1, Consistency::Sequential);
        let g = NDArray::ones(&[1]);
        assert!(kv.push("nope", &g, 0).is_err());
        assert!(kv.pull("nope", &g, 0).is_err());
    }

    #[test]
    fn aggregates_across_devices_before_update() {
        // 4 devices push 1.0 each; lr=1 -> w decreases by 4 per round.
        let (kv, e) = store(4, Consistency::Sequential);
        let w0 = NDArray::zeros_on(&[1], e.clone());
        kv.init("w", &w0).unwrap();
        for d in 0..4 {
            let g = NDArray::from_vec_on(&[1], vec![1.0], e.clone());
            kv.push("w", &g, d).unwrap();
        }
        let out = NDArray::zeros_on(&[1], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![-4.0]);
    }

    #[test]
    fn partial_round_does_not_update() {
        let (kv, e) = store(2, Consistency::Sequential);
        kv.init("w", &NDArray::zeros_on(&[1], e.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], e.clone()), 0).unwrap(); // 1 of 2
        let out = NDArray::zeros_on(&[1], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.0], "no update until round completes");
    }

    #[test]
    fn paper_training_loop_shape() {
        // while(1) { kv.pull(w); forward_backward; kv.push(g) } — here a
        // synthetic gradient descent on f(w)=w^2.
        let engine = create(EngineKind::Threaded, 4);
        let opt = Arc::new(Sgd::new(0.1));
        let kv = LocalKVStore::new(engine.clone(), 1, opt, Consistency::Sequential);
        kv.init("w", &NDArray::from_vec_on(&[1], vec![4.0], engine.clone())).unwrap();
        let w = NDArray::zeros_on(&[1], engine.clone());
        for _ in 0..50 {
            kv.pull("w", &w, 0).unwrap();
            let cur = w.to_vec()[0];
            let g = NDArray::from_vec_on(&[1], vec![2.0 * cur], engine.clone());
            kv.push("w", &g, 0).unwrap();
        }
        kv.flush();
        kv.pull("w", &w, 0).unwrap();
        let final_w = w.to_vec()[0];
        assert!(final_w.abs() < 0.1, "{final_w}");
    }

    #[test]
    fn version_stamped_pull_skips_redundant_copies() {
        // Regression (ISSUE 4 satellite): a pull whose version is
        // unchanged since this device's last pull into the same array
        // must not schedule a copy — and must still be correct.
        let (kv, e) = store(1, Consistency::Sequential);
        kv.init("w", &NDArray::from_vec_on(&[2], vec![3.0, 4.0], e.clone())).unwrap();
        let out = NDArray::zeros_on(&[2], e.clone());
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!((kv.pull_stats().copies, kv.pull_stats().skips), (1, 0));
        assert_eq!(out.to_vec(), vec![3.0, 4.0]);
        // same device, same array, no update since -> skipped, still right
        kv.pull("w", &out, 0).unwrap();
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!((kv.pull_stats().copies, kv.pull_stats().skips), (1, 2));
        assert_eq!(out.to_vec(), vec![3.0, 4.0]);
        // a different destination array must copy even at the same version
        let other = NDArray::zeros_on(&[2], e.clone());
        kv.pull("w", &other, 0).unwrap();
        kv.flush();
        assert_eq!((kv.pull_stats().copies, kv.pull_stats().skips), (2, 2));
        assert_eq!(other.to_vec(), vec![3.0, 4.0]);
        // an update invalidates the stamp: next pull copies the new value
        kv.push("w", &NDArray::from_vec_on(&[2], vec![1.0, 1.0], e.clone()), 0).unwrap();
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!((kv.pull_stats().copies, kv.pull_stats().skips), (3, 2));
        assert_eq!(out.to_vec(), vec![2.0, 3.0], "lr=1: w -= g");
    }

    #[test]
    fn eventual_pull_skips_when_snapshot_unchanged() {
        let (kv, e) = store(2, Consistency::Eventual);
        kv.init("w", &NDArray::from_vec_on(&[1], vec![5.0], e.clone())).unwrap();
        let out = NDArray::zeros_on(&[1], e.clone());
        kv.pull("w", &out, 0).unwrap();
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![5.0]);
        let s = kv.pull_stats();
        assert_eq!((s.copies, s.skips), (1, 1));
        // complete a round; once the snapshot commits, the pull re-copies
        for d in 0..2 {
            kv.push("w", &NDArray::from_vec_on(&[1], vec![0.5], e.clone()), d).unwrap();
        }
        kv.flush(); // snapshot committed
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![4.0], "5 - (0.5+0.5)");
        assert_eq!(kv.pull_stats().copies, 2);
    }

    #[test]
    fn snapshot_cadence_and_age_reporting() {
        // snapshot_every(2): after one completed round the committed
        // snapshot is still round 0, and the eventual pull reports age 1;
        // after the second round the snapshot catches up (age 0).
        let (kv, e) = store(1, Consistency::Eventual);
        kv.snapshot_every(2);
        kv.init("w", &NDArray::from_vec_on(&[1], vec![8.0], e.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], e.clone()), 0).unwrap();
        kv.flush();
        let out = NDArray::zeros_on(&[1], e.clone());
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![8.0], "snapshot still at round 0");
        assert_eq!(kv.pull_stats().last_snap_age, 1);
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], e.clone()), 0).unwrap();
        kv.flush();
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![6.0], "round-2 snapshot committed");
        assert_eq!(kv.pull_stats().last_snap_age, 0);
        assert_eq!(kv.pull_stats().max_snap_age, 1);
        assert_eq!(kv.snapshot_round("w").unwrap(), 2);
    }

    #[test]
    fn bounded_delay_pull_respects_staleness_ceiling() {
        // BoundedDelay(1): a pull after 3 rounds must serve a snapshot of
        // round >= 2; with a coarse cadence it demands one itself.
        let engine = create(EngineKind::Threaded, 4);
        let opt = Arc::new(Sgd::new(1.0));
        let kv = LocalKVStore::new(engine.clone(), 1, opt, Consistency::BoundedDelay(1));
        kv.snapshot_every(100); // never on cadence: pulls must demand
        kv.init("w", &NDArray::from_vec_on(&[1], vec![10.0], engine.clone())).unwrap();
        for _ in 0..3 {
            kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], engine.clone()), 0).unwrap();
        }
        let out = NDArray::zeros_on(&[1], engine);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // The demanded snapshot reads the weight *after all 3 scheduled
        // updates* (engine-ordered), so the pull observes round 3, age 0
        // — and never anything older than round 2.
        assert_eq!(out.to_vec(), vec![7.0]);
        assert!(kv.pull_stats().max_snap_age <= 1, "{:?}", kv.pull_stats());
    }

    #[test]
    fn bounded_delay_zero_matches_sequential_values() {
        let (kv, e) = store(1, Consistency::BoundedDelay(0));
        kv.init("w", &NDArray::from_vec_on(&[2], vec![1.0, 2.0], e.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[2], vec![0.5, 0.5], e.clone()), 0).unwrap();
        let out = NDArray::zeros_on(&[2], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.5, 1.5], "k=0 pulls are fully fresh");
        assert_eq!(kv.pull_stats().max_snap_age, 0);
    }

    #[test]
    fn pull_committed_serves_whole_committed_rounds() {
        // The live-serving read path: every pull_committed must return a
        // buffer from exactly one committed round — with uniform-valued
        // weights a torn copy would mix two different values.
        let (kv, e) = store(1, Consistency::Sequential);
        kv.init("w", &NDArray::from_vec_on(&[64], vec![100.0; 64], e.clone())).unwrap();
        assert_eq!(kv.value_len("w").unwrap(), 64);
        for _ in 0..5 {
            kv.push("w", &NDArray::from_vec_on(&[64], vec![1.0; 64], e.clone()), 0).unwrap();
            let out = NDArray::zeros_on(&[64], e.clone());
            let round = kv.pull_committed("w", &out).unwrap();
            let v = out.to_vec();
            assert!(v.iter().all(|x| x.to_bits() == v[0].to_bits()), "torn read: {v:?}");
            assert_eq!(v[0], 100.0 - round as f32, "value matches the committed round");
        }
        kv.flush();
    }

    #[test]
    fn staged_parts_reduce_in_part_order_regardless_of_arrival() {
        // Rounding-sensitive values: (1e8 + 1) - 1e8 == 0.0 in f32 when
        // summed in part order 0,1,2.  Any arrival order must produce
        // exactly that.
        let vals = [1.0e8f32, 1.0, -1.0e8];
        let mut results = Vec::new();
        for arrival in [[0usize, 1, 2], [2, 0, 1], [1, 2, 0]] {
            let (kv, e) = store(3, Consistency::Sequential);
            kv.init("w", &NDArray::zeros_on(&[1], e.clone())).unwrap();
            for part in arrival {
                kv.push_part("w", &[vals[part]], part).unwrap();
            }
            let out = NDArray::zeros_on(&[1], e);
            kv.pull("w", &out, 0).unwrap();
            kv.flush();
            results.push(out.to_vec()[0]);
        }
        // lr=1: w = 0 - merged; merged = (1e8 + 1) + (-1e8) = 0.0 exactly
        // (1e8 + 1 rounds to 1e8 in f32) — and bitwise identical for
        // every arrival order because the reduce is in part order.
        assert_eq!(results, vec![0.0; 3]);
        assert!(results.iter().all(|r| r.to_bits() == results[0].to_bits()));
    }

    #[test]
    fn staged_partial_round_does_not_update() {
        let (kv, e) = store(2, Consistency::Sequential);
        kv.init("w", &NDArray::zeros_on(&[1], e.clone())).unwrap();
        kv.push_part("w", &[1.0], 0).unwrap();
        let out = NDArray::zeros_on(&[1], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.0]);
        // completing the round applies the merge
        kv.push_part("w", &[2.0], 1).unwrap();
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![-3.0]);
    }

    #[test]
    fn staged_part_misuse_rejected() {
        let (kv, e) = store(2, Consistency::Sequential);
        kv.init("w", &NDArray::zeros_on(&[2], e.clone())).unwrap();
        assert!(kv.push_part("nope", &[0.0; 2], 0).is_err(), "unknown key");
        assert!(kv.push_part("w", &[0.0; 2], 2).is_err(), "part out of range");
        assert!(kv.push_part("w", &[0.0; 3], 0).is_err(), "length mismatch");
        kv.push_part("w", &[1.0; 2], 0).unwrap();
        assert!(kv.push_part("w", &[1.0; 2], 0).is_err(), "double delivery");
        // mixing the legacy arrival-order path into a staged round
        assert!(kv.push("w", &NDArray::ones(&[2]), 1).is_err());
        kv.flush();
    }

    #[test]
    fn eventual_pull_does_not_block_on_round() {
        let (kv, e) = store(2, Consistency::Eventual);
        kv.init("w", &NDArray::from_vec_on(&[1], vec![7.0], e.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], e.clone()), 0).unwrap(); // partial round
        let out = NDArray::zeros_on(&[1], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // sees the initial snapshot (no committed update yet)
        assert_eq!(out.to_vec(), vec![7.0]);
    }
}

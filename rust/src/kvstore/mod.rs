//! KVStore — data synchronization over devices and machines
//! (paper §2.3, implementation §3.3).
//!
//! Two primitives: **push** a gradient for a key, **pull** the current
//! weight.  A user-defined *updater* (usually an [`Optimizer`]) merges
//! pushed values into the stored weight.  Consistency is controlled by a
//! [`Consistency`] model: `Sequential` pulls observe every push the caller
//! issued before; `Eventual` pulls return immediately with a possibly
//! stale snapshot (paper: *"intra- is sequential and inter- is
//! eventual"*).
//!
//! Two implementations:
//!
//! * [`LocalKVStore`] — the level-1 server: aggregates pushes from the
//!   devices (worker threads) of one machine, applies the updater once
//!   per round.  Push/pull are engine operations, so they schedule
//!   seamlessly against compute (§3.3: *"we use the engine to schedule
//!   the KVStore operations"*).
//! * [`DistKVStore`](dist::DistKVStore) — the two-level structure: a
//!   level-1 local aggregator whose merged gradient is forwarded to the
//!   level-2 TCP [server](server), cutting inter-machine bandwidth by the
//!   per-machine device count.

pub mod dist;
pub mod server;
pub mod wire;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::ndarray::NDArray;
use crate::optimizer::Optimizer;

/// Consistency model for pulls (paper §2.3: *"model divergence is
/// controlled via consistency model"*).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Consistency {
    /// A pull observes all pushes issued before it by this worker.
    Sequential,
    /// A pull may return a stale snapshot (no blocking).
    Eventual,
}

/// The push/pull interface shared by local and distributed stores.
pub trait KVStore: Send + Sync {
    /// Register a key with its initial weight value.
    fn init(&self, key: &str, value: &NDArray) -> Result<()>;

    /// Push a gradient contribution for `key` from device `device`.
    fn push(&self, key: &str, grad: &NDArray, device: usize) -> Result<()>;

    /// Pull the current weight for `key` into `out`.
    fn pull(&self, key: &str, out: &NDArray, device: usize) -> Result<()>;

    /// Block until all outstanding store operations have been applied.
    fn flush(&self);

    /// The number of devices pushing per round.
    fn num_devices(&self) -> usize;

    /// The consistency model in effect.
    fn consistency(&self) -> Consistency;
}

struct KeyState {
    weight: NDArray,
    /// Gradient accumulation buffer for the current round.
    accum: NDArray,
    /// Devices that have pushed this round.
    pushed: usize,
    /// Committed snapshot for eventual-consistency pulls.
    snapshot: Arc<Mutex<Vec<f32>>>,
}

/// Level-1 (intra-machine) key-value store over the dependency engine.
pub struct LocalKVStore {
    engine: EngineRef,
    num_devices: usize,
    consistency: Consistency,
    updater: Arc<dyn Optimizer>,
    keys: Mutex<HashMap<String, KeyState>>,
}

impl LocalKVStore {
    /// Create a store aggregating `num_devices` pushes per round and
    /// applying `updater` to merge them.
    pub fn new(
        engine: EngineRef,
        num_devices: usize,
        updater: Arc<dyn Optimizer>,
        consistency: Consistency,
    ) -> Self {
        LocalKVStore {
            engine,
            num_devices: num_devices.max(1),
            consistency,
            updater,
            keys: Mutex::new(HashMap::new()),
        }
    }
}

impl KVStore for LocalKVStore {
    fn init(&self, key: &str, value: &NDArray) -> Result<()> {
        let mut keys = self.keys.lock().unwrap();
        if keys.contains_key(key) {
            return Err(Error::kv(format!("key '{key}' already initialized")));
        }
        let weight = NDArray::zeros_on(value.shape(), self.engine.clone());
        weight.copy_from_(value);
        let accum = NDArray::zeros_on(value.shape(), self.engine.clone());
        let snapshot = Arc::new(Mutex::new(value.to_vec()));
        keys.insert(key.to_string(), KeyState { weight, accum, pushed: 0, snapshot });
        Ok(())
    }

    fn push(&self, key: &str, grad: &NDArray, _device: usize) -> Result<()> {
        let mut keys = self.keys.lock().unwrap();
        let st = keys.get_mut(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        if st.pushed == 0 {
            st.accum.zero_();
        }
        st.accum.add_(grad);
        st.pushed += 1;
        if st.pushed == self.num_devices {
            st.pushed = 0;
            // merged gradient ready: run the user updater, then refresh
            // the eventual-consistency snapshot.
            self.updater.update(key, &st.weight, &st.accum);
            let snap = Arc::clone(&st.snapshot);
            let ws = st.weight.storage();
            self.engine.push(
                "kv.snapshot",
                vec![st.weight.var()],
                vec![],
                Box::new(move || {
                    let mut s = snap.lock().unwrap();
                    let w = unsafe { ws.slice() };
                    s.clear();
                    s.extend_from_slice(w);
                }),
            );
        }
        Ok(())
    }

    fn pull(&self, key: &str, out: &NDArray, _device: usize) -> Result<()> {
        let keys = self.keys.lock().unwrap();
        let st = keys.get(key).ok_or_else(|| Error::kv(format!("unknown key '{key}'")))?;
        match self.consistency {
            Consistency::Sequential => {
                // Engine dependency on the weight var orders this pull
                // after every previously-scheduled update.
                out.copy_from_(&st.weight);
            }
            Consistency::Eventual => {
                // Snapshot read: no dependency on in-flight updates.
                let snap = Arc::clone(&st.snapshot);
                let os = out.storage();
                self.engine.push(
                    "kv.pull_eventual",
                    vec![],
                    vec![out.var()],
                    Box::new(move || {
                        let s = snap.lock().unwrap();
                        unsafe { os.slice_mut() }.copy_from_slice(&s);
                    }),
                );
            }
        }
        Ok(())
    }

    fn flush(&self) {
        self.engine.wait_all();
    }

    fn num_devices(&self) -> usize {
        self.num_devices
    }

    fn consistency(&self) -> Consistency {
        self.consistency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{create, EngineKind};
    use crate::optimizer::Sgd;

    fn store(devices: usize, consistency: Consistency) -> (LocalKVStore, EngineRef) {
        let engine = create(EngineKind::Threaded, 4);
        let opt = Arc::new(Sgd::new(1.0)); // lr=1 -> w -= sum(grads)
        (LocalKVStore::new(engine.clone(), devices, opt, consistency), engine)
    }

    #[test]
    fn init_push_pull_single_device() {
        let (kv, e) = store(1, Consistency::Sequential);
        let w0 = NDArray::from_vec_on(&[2], vec![1.0, 2.0], e.clone());
        kv.init("w", &w0).unwrap();
        let g = NDArray::from_vec_on(&[2], vec![0.5, 0.5], e.clone());
        kv.push("w", &g, 0).unwrap();
        let out = NDArray::zeros_on(&[2], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.5, 1.5]);
    }

    #[test]
    fn double_init_rejected() {
        let (kv, _e) = store(1, Consistency::Sequential);
        let w = NDArray::ones(&[1]);  // engine-local state untouched by init
        kv.init("w", &w).unwrap();
        assert!(kv.init("w", &w).is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let (kv, _e) = store(1, Consistency::Sequential);
        let g = NDArray::ones(&[1]);
        assert!(kv.push("nope", &g, 0).is_err());
        assert!(kv.pull("nope", &g, 0).is_err());
    }

    #[test]
    fn aggregates_across_devices_before_update() {
        // 4 devices push 1.0 each; lr=1 -> w decreases by 4 per round.
        let (kv, e) = store(4, Consistency::Sequential);
        let w0 = NDArray::zeros_on(&[1], e.clone());
        kv.init("w", &w0).unwrap();
        for d in 0..4 {
            let g = NDArray::from_vec_on(&[1], vec![1.0], e.clone());
            kv.push("w", &g, d).unwrap();
        }
        let out = NDArray::zeros_on(&[1], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![-4.0]);
    }

    #[test]
    fn partial_round_does_not_update() {
        let (kv, e) = store(2, Consistency::Sequential);
        kv.init("w", &NDArray::zeros_on(&[1], e.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], e.clone()), 0).unwrap(); // 1 of 2
        let out = NDArray::zeros_on(&[1], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        assert_eq!(out.to_vec(), vec![0.0], "no update until round completes");
    }

    #[test]
    fn paper_training_loop_shape() {
        // while(1) { kv.pull(w); forward_backward; kv.push(g) } — here a
        // synthetic gradient descent on f(w)=w^2.
        let engine = create(EngineKind::Threaded, 4);
        let opt = Arc::new(Sgd::new(0.1));
        let kv = LocalKVStore::new(engine.clone(), 1, opt, Consistency::Sequential);
        kv.init("w", &NDArray::from_vec_on(&[1], vec![4.0], engine.clone())).unwrap();
        let w = NDArray::zeros_on(&[1], engine.clone());
        for _ in 0..50 {
            kv.pull("w", &w, 0).unwrap();
            let cur = w.to_vec()[0];
            let g = NDArray::from_vec_on(&[1], vec![2.0 * cur], engine.clone());
            kv.push("w", &g, 0).unwrap();
        }
        kv.flush();
        kv.pull("w", &w, 0).unwrap();
        let final_w = w.to_vec()[0];
        assert!(final_w.abs() < 0.1, "{final_w}");
    }

    #[test]
    fn eventual_pull_does_not_block_on_round() {
        let (kv, e) = store(2, Consistency::Eventual);
        kv.init("w", &NDArray::from_vec_on(&[1], vec![7.0], e.clone())).unwrap();
        kv.push("w", &NDArray::from_vec_on(&[1], vec![1.0], e.clone()), 0).unwrap(); // partial round
        let out = NDArray::zeros_on(&[1], e);
        kv.pull("w", &out, 0).unwrap();
        kv.flush();
        // sees the initial snapshot (no committed update yet)
        assert_eq!(out.to_vec(), vec![7.0]);
    }
}

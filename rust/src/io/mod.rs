//! Data I/O (paper §2.4): RecordIO packing, data iterators, multi-threaded
//! prefetching, and synthetic dataset generators (the stand-in for
//! ILSVRC12 — see DESIGN.md §Substitutions).

pub mod checkpoint;
pub mod partition;
pub mod prefetch;
pub mod recordio;
pub mod synth;

use crate::engine::EngineRef;
use crate::error::Result;
use crate::ndarray::NDArray;
use crate::util::Rng;

pub use partition::{
    shard_ranges, shard_ranges_weighted, split_batch, split_batch_weighted, PartitionIter,
};
pub use prefetch::PrefetchIter;
pub use recordio::{Example, RecordReader, RecordWriter};

/// One minibatch: features `[batch, ...]` and labels `[batch]`.
#[derive(Clone, Debug)]
pub struct DataBatch {
    /// Feature tensor.
    pub data: NDArray,
    /// Label vector.
    pub label: NDArray,
}

/// A stream of minibatches (paper's data iterator).
pub trait DataIter: Send {
    /// Next minibatch, or `None` at epoch end.
    fn next_batch(&mut self) -> Option<DataBatch>;
    /// Rewind to the start of the epoch (optionally reshuffling).
    fn reset(&mut self);
    /// Batch size.
    fn batch_size(&self) -> usize;
}

/// In-memory dataset iterator with optional shuffling.
pub struct ArrayDataIter {
    features: Vec<f32>,
    labels: Vec<f32>,
    feat_shape: Vec<usize>, // per-example shape
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    shuffle: bool,
    rng: Rng,
    engine: EngineRef,
}

impl ArrayDataIter {
    /// Build from flat feature/label buffers. `feat_shape` excludes the
    /// example dimension.
    pub fn new(
        features: Vec<f32>,
        labels: Vec<f32>,
        feat_shape: &[usize],
        batch: usize,
        shuffle: bool,
        engine: EngineRef,
    ) -> Self {
        let per: usize = feat_shape.iter().product();
        assert_eq!(features.len() % per, 0);
        let n = features.len() / per;
        assert_eq!(labels.len(), n);
        ArrayDataIter {
            features,
            labels,
            feat_shape: feat_shape.to_vec(),
            order: (0..n).collect(),
            cursor: 0,
            batch,
            shuffle,
            rng: Rng::seed_from_u64(0x17e5),
            engine,
        }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the dataset is empty.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

impl DataIter for ArrayDataIter {
    fn next_batch(&mut self) -> Option<DataBatch> {
        if self.cursor + self.batch > self.order.len() {
            return None; // drop last partial batch (like MXNet's default)
        }
        let per: usize = self.feat_shape.iter().product();
        let mut data = Vec::with_capacity(self.batch * per);
        let mut label = Vec::with_capacity(self.batch);
        for i in 0..self.batch {
            let idx = self.order[self.cursor + i];
            data.extend_from_slice(&self.features[idx * per..(idx + 1) * per]);
            label.push(self.labels[idx]);
        }
        self.cursor += self.batch;
        let mut shape = vec![self.batch];
        shape.extend_from_slice(&self.feat_shape);
        Some(DataBatch {
            data: NDArray::from_vec_on(&shape, data, self.engine.clone()),
            label: NDArray::from_vec_on(&[self.batch], label, self.engine.clone()),
        })
    }

    fn reset(&mut self) {
        self.cursor = 0;
        if self.shuffle {
            self.rng.shuffle(&mut self.order);
        }
    }

    fn batch_size(&self) -> usize {
        self.batch
    }
}

/// Iterator over a RecordIO file of [`Example`]s (sequential scan per
/// epoch; pair with [`PrefetchIter`] to hide decode latency).
pub struct RecordFileIter {
    path: std::path::PathBuf,
    reader: RecordReader,
    batch: usize,
    engine: EngineRef,
    feat_shape: Option<Vec<usize>>,
}

impl RecordFileIter {
    /// Open a RecordIO file for iteration.
    pub fn open(path: impl AsRef<std::path::Path>, batch: usize, engine: EngineRef) -> Result<Self> {
        Ok(RecordFileIter {
            path: path.as_ref().to_path_buf(),
            reader: RecordReader::open(&path)?,
            batch,
            engine,
            feat_shape: None,
        })
    }
}

impl DataIter for RecordFileIter {
    fn next_batch(&mut self) -> Option<DataBatch> {
        let mut data = Vec::new();
        let mut label = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let payload = self.reader.next_record().ok()??;
            let ex = Example::from_bytes(&payload).ok()?;
            match &self.feat_shape {
                None => self.feat_shape = Some(ex.shape.clone()),
                Some(s) => {
                    if *s != ex.shape {
                        return None;
                    }
                }
            }
            data.extend_from_slice(&ex.data);
            label.push(ex.label);
        }
        let mut shape = vec![self.batch];
        shape.extend_from_slice(self.feat_shape.as_ref().unwrap());
        Some(DataBatch {
            data: NDArray::from_vec_on(&shape, data, self.engine.clone()),
            label: NDArray::from_vec_on(&[self.batch], label, self.engine.clone()),
        })
    }

    fn reset(&mut self) {
        if let Ok(r) = RecordReader::open(&self.path) {
            self.reader = r;
        }
    }

    fn batch_size(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::default_engine;

    #[test]
    fn array_iter_batches_and_drops_partial() {
        let eng = default_engine();
        let n = 10;
        let feats: Vec<f32> = (0..n * 3).map(|v| v as f32).collect();
        let labels: Vec<f32> = (0..n).map(|v| v as f32).collect();
        let mut it = ArrayDataIter::new(feats, labels, &[3], 4, false, eng);
        let b1 = it.next_batch().unwrap();
        assert_eq!(b1.data.shape(), &[4, 3]);
        assert_eq!(b1.label.to_vec(), vec![0.0, 1.0, 2.0, 3.0]);
        let _b2 = it.next_batch().unwrap();
        assert!(it.next_batch().is_none(), "partial batch dropped");
        it.reset();
        assert!(it.next_batch().is_some());
    }

    #[test]
    fn shuffle_changes_order_but_not_multiset() {
        let eng = default_engine();
        let n = 32;
        let feats: Vec<f32> = (0..n).map(|v| v as f32).collect();
        let labels = feats.clone();
        let mut it = ArrayDataIter::new(feats, labels, &[1], 32, true, eng);
        let first = it.next_batch().unwrap().label.to_vec();
        it.reset();
        let second = it.next_batch().unwrap().label.to_vec();
        assert_ne!(first, second, "shuffle should reorder");
        let mut a = first.clone();
        let mut b = second.clone();
        a.sort_by(f32::total_cmp);
        b.sort_by(f32::total_cmp);
        assert_eq!(a, b);
    }

    #[test]
    fn record_file_iter_roundtrip() {
        let eng = default_engine();
        let mut p = std::env::temp_dir();
        p.push(format!("mixnet_iter_{}.rec", std::process::id()));
        let mut w = RecordWriter::create(&p).unwrap();
        for i in 0..6 {
            let ex = Example { label: i as f32, shape: vec![2], data: vec![i as f32; 2] };
            w.write_record(&ex.to_bytes()).unwrap();
        }
        w.finish().unwrap();
        let mut it = RecordFileIter::open(&p, 3, eng).unwrap();
        let b = it.next_batch().unwrap();
        assert_eq!(b.label.to_vec(), vec![0.0, 1.0, 2.0]);
        assert_eq!(b.data.shape(), &[3, 2]);
        let b2 = it.next_batch().unwrap();
        assert_eq!(b2.label.to_vec(), vec![3.0, 4.0, 5.0]);
        assert!(it.next_batch().is_none());
        it.reset();
        assert!(it.next_batch().is_some());
        std::fs::remove_file(p).unwrap();
    }
}

//! Synthetic dataset generators — the stand-in for ILSVRC12 and the
//! convnet-benchmarks inputs (DESIGN.md §Substitutions).
//!
//! `class_clusters` draws each class from a Gaussian around a random
//! class centroid, giving a learnable classification problem whose
//! difficulty is controlled by the noise/centroid-separation ratio;
//! `images` produces NCHW tensors the model zoo consumes; both can be
//! packed into RecordIO via [`write_recordio`].

use crate::error::Result;
use crate::io::recordio::{Example, RecordWriter};
use crate::util::Rng;

/// A generated in-memory dataset.
#[derive(Debug, Clone)]
pub struct SynthDataset {
    /// Flat features, `n * prod(feat_shape)`.
    pub features: Vec<f32>,
    /// Labels, length `n`.
    pub labels: Vec<f32>,
    /// Per-example feature shape.
    pub feat_shape: Vec<usize>,
}

impl SynthDataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

/// Gaussian class-cluster dataset: `n` examples over `classes` classes in
/// `dim` dimensions; `noise` is the intra-class std relative to unit
/// centroid scale.
pub fn class_clusters(n: usize, classes: usize, dim: usize, noise: f32, seed: u64) -> SynthDataset {
    let mut rng = Rng::seed_from_u64(seed);
    let centroids: Vec<f32> = (0..classes * dim).map(|_| rng.normal()).collect();
    let mut features = Vec::with_capacity(n * dim);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % classes;
        for d in 0..dim {
            features.push(centroids[c * dim + d] + noise * rng.normal());
        }
        labels.push(c as f32);
    }
    // interleave classes deterministically, then shuffle example order
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut f2 = Vec::with_capacity(n * dim);
    let mut l2 = Vec::with_capacity(n);
    for &idx in &order {
        f2.extend_from_slice(&features[idx * dim..(idx + 1) * dim]);
        l2.push(labels[idx]);
    }
    SynthDataset { features: f2, labels: l2, feat_shape: vec![dim] }
}

/// Synthetic NCHW image dataset: class-dependent mean patterns plus noise
/// (exercises the conv stack the same way decoded JPEGs would).
pub fn images(n: usize, classes: usize, c: usize, h: usize, w: usize, noise: f32, seed: u64) -> SynthDataset {
    let mut rng = Rng::seed_from_u64(seed);
    let per = c * h * w;
    // low-frequency class patterns
    let patterns: Vec<f32> = (0..classes * per)
        .map(|i| {
            let x = (i % w) as f32 / w as f32;
            let cls = i / per;
            ((x * (cls + 1) as f32 * std::f32::consts::PI).sin() + rng.normal() * 0.1) * 0.5
        })
        .collect();
    let mut features = Vec::with_capacity(n * per);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let cls = i % classes;
        for p in 0..per {
            features.push(patterns[cls * per + p] + noise * rng.normal());
        }
        labels.push(cls as f32);
    }
    SynthDataset { features, labels, feat_shape: vec![c, h, w] }
}

/// Pack a dataset into a RecordIO file; returns the record index.
pub fn write_recordio(ds: &SynthDataset, path: impl AsRef<std::path::Path>) -> Result<Vec<u64>> {
    let per: usize = ds.feat_shape.iter().product();
    let mut w = RecordWriter::create(path)?;
    for i in 0..ds.len() {
        let ex = Example {
            label: ds.labels[i],
            shape: ds.feat_shape.clone(),
            data: ds.features[i * per..(i + 1) * per].to_vec(),
        };
        w.write_record(&ex.to_bytes())?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clusters_are_balanced_and_separable() {
        let ds = class_clusters(200, 4, 8, 0.1, 42);
        assert_eq!(ds.len(), 200);
        // class balance
        for c in 0..4 {
            let cnt = ds.labels.iter().filter(|&&l| l == c as f32).count();
            assert_eq!(cnt, 50);
        }
        // nearest-centroid classification should be near perfect at low noise
        let mut centroids = vec![0.0f32; 4 * 8];
        let mut counts = [0usize; 4];
        for i in 0..ds.len() {
            let c = ds.labels[i] as usize;
            counts[c] += 1;
            for d in 0..8 {
                centroids[c * 8 + d] += ds.features[i * 8 + d];
            }
        }
        for c in 0..4 {
            for d in 0..8 {
                centroids[c * 8 + d] /= counts[c] as f32;
            }
        }
        let mut correct = 0;
        for i in 0..ds.len() {
            let x = &ds.features[i * 8..(i + 1) * 8];
            let best = (0..4)
                .min_by(|&a, &b| {
                    let da: f32 =
                        (0..8).map(|d| (x[d] - centroids[a * 8 + d]).powi(2)).sum();
                    let db: f32 =
                        (0..8).map(|d| (x[d] - centroids[b * 8 + d]).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.labels[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 190, "only {correct}/200 separable");
    }

    #[test]
    fn images_shape_and_determinism() {
        let a = images(10, 3, 1, 8, 8, 0.2, 7);
        let b = images(10, 3, 1, 8, 8, 0.2, 7);
        assert_eq!(a.features, b.features);
        assert_eq!(a.feat_shape, vec![1, 8, 8]);
        assert_eq!(a.features.len(), 10 * 64);
    }

    #[test]
    fn recordio_pack_roundtrip() {
        let ds = class_clusters(10, 2, 4, 0.1, 1);
        let mut p = std::env::temp_dir();
        p.push(format!("mixnet_synth_{}.rec", std::process::id()));
        let idx = write_recordio(&ds, &p).unwrap();
        assert_eq!(idx.len(), 10);
        let mut r = crate::io::RecordReader::open(&p).unwrap();
        let first = Example::from_bytes(&r.next_record().unwrap().unwrap()).unwrap();
        assert_eq!(first.label, ds.labels[0]);
        assert_eq!(first.data, &ds.features[0..4]);
        std::fs::remove_file(p).unwrap();
    }
}

//! Model checkpointing (paper §2.1: *"other functions, such as load,
//! save, memory estimation, and visualization, are also provided"*).
//!
//! A checkpoint is a single binary file holding named f32 tensors with
//! shapes — the parameter side of MXNet's `save_checkpoint` (the symbol
//! side is code in this reproduction, so only parameters serialize).
//!
//! Format (little-endian): magic u32, count u32, then per tensor:
//! name (u32 len + utf8), ndim u32, dims u32*, data f32*.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::ndarray::NDArray;

/// Checkpoint file magic + version.
pub const CKPT_MAGIC: u32 = 0x6d78_6b01;

/// Save named arrays to `path` (sorted by name for determinism).
pub fn save(path: impl AsRef<Path>, params: &HashMap<String, NDArray>) -> Result<()> {
    let mut names: Vec<&String> = params.keys().collect();
    names.sort();
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(&CKPT_MAGIC.to_le_bytes());
    buf.extend_from_slice(&(names.len() as u32).to_le_bytes());
    for name in names {
        let arr = &params[name];
        let data = arr.to_vec(); // waits for pending engine ops
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(&(arr.shape().len() as u32).to_le_bytes());
        for &d in arr.shape() {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for x in &data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&buf)?;
    Ok(())
}

/// Load a checkpoint into new arrays on `engine`.
pub fn load(path: impl AsRef<Path>, engine: EngineRef) -> Result<HashMap<String, NDArray>> {
    let mut bytes = Vec::new();
    std::fs::File::open(&path)?.read_to_end(&mut bytes)?;
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            return Err(Error::DataIo("checkpoint: truncated".into()));
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let u32_at = |pos: &mut usize| -> Result<u32> {
        Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
    };
    if u32_at(&mut pos)? != CKPT_MAGIC {
        return Err(Error::DataIo("checkpoint: bad magic".into()));
    }
    let count = u32_at(&mut pos)? as usize;
    let mut out = HashMap::with_capacity(count);
    for _ in 0..count {
        let nlen = u32_at(&mut pos)? as usize;
        let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())
            .map_err(|_| Error::DataIo("checkpoint: bad utf8 name".into()))?;
        let ndim = u32_at(&mut pos)? as usize;
        if ndim > 8 {
            return Err(Error::DataIo(format!("checkpoint: ndim {ndim} too large")));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32_at(&mut pos)? as usize);
        }
        let size: usize = shape.iter().product();
        let raw = take(&mut pos, size * 4)?;
        let data: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        out.insert(name, NDArray::from_vec_on(&shape, data, engine.clone()));
    }
    if pos != bytes.len() {
        return Err(Error::DataIo("checkpoint: trailing bytes".into()));
    }
    Ok(out)
}

impl crate::module::Module {
    /// Save this module's parameters (paper's `save_checkpoint`).
    pub fn save_params(&self, path: impl AsRef<Path>) -> Result<()> {
        let map: HashMap<String, NDArray> = self
            .param_names()
            .iter()
            .map(|n| (n.clone(), self.param(n).unwrap().clone()))
            .collect();
        save(path, &map)
    }

    /// Overwrite this module's parameters from a checkpoint (must be
    /// bound; shapes must match).
    pub fn load_params(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let engine = self.param(self.param_names().first().ok_or_else(|| {
            Error::Bind("module has no parameters (bind first)".into())
        })?).unwrap().engine();
        let loaded = load(path, engine)?;
        for name in self.param_names().to_vec() {
            let src = loaded.get(&name).ok_or_else(|| {
                Error::DataIo(format!("checkpoint missing parameter '{name}'"))
            })?;
            let dst = self.param(&name).unwrap();
            if dst.shape() != src.shape() {
                return Err(Error::DataIo(format!(
                    "checkpoint '{name}': shape {:?} != bound {:?}",
                    src.shape(),
                    dst.shape()
                )));
            }
            dst.copy_from_(src);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::default_engine;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mixnet_ckpt_{}_{tag}.bin", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_names_shapes_values() {
        let p = tmp("rt");
        let mut m = HashMap::new();
        m.insert("w".to_string(), NDArray::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 7.0, -0.25]));
        m.insert("b".to_string(), NDArray::from_vec(&[3], vec![0.1, 0.2, 0.3]));
        save(&p, &m).unwrap();
        let back = load(&p, default_engine()).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["w"].shape(), &[2, 3]);
        assert_eq!(back["w"].to_vec(), m["w"].to_vec());
        assert_eq!(back["b"].to_vec(), m["b"].to_vec());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corrupt_magic_rejected() {
        let p = tmp("magic");
        save(&p, &HashMap::new()).unwrap();
        let mut b = std::fs::read(&p).unwrap();
        b[0] ^= 0xff;
        std::fs::write(&p, b).unwrap();
        assert!(load(&p, default_engine()).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn truncation_rejected() {
        let p = tmp("trunc");
        let mut m = HashMap::new();
        m.insert("w".to_string(), NDArray::from_vec(&[64], vec![1.0; 64]));
        save(&p, &m).unwrap();
        let b = std::fs::read(&p).unwrap();
        std::fs::write(&p, &b[..b.len() - 10]).unwrap();
        assert!(load(&p, default_engine()).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn module_save_load_resumes_training() {
        use crate::executor::BindConfig;
        use crate::io::{synth::class_clusters, ArrayDataIter};
        use crate::models::mlp;
        use crate::module::{Module, UpdateMode};
        use crate::optimizer::Sgd;
        use std::sync::Arc;

        let p = tmp("resume");
        let engine = crate::engine::create(crate::engine::EngineKind::Threaded, 2);
        let ds = class_clusters(256, 4, 16, 0.3, 9);
        let mut iter =
            ArrayDataIter::new(ds.features, ds.labels, &[16], 32, true, engine.clone());
        let model = mlp(&[32], 16, 4);
        let shapes = model.param_shapes(32).unwrap();
        let mut m = Module::new(model.symbol, engine.clone());
        m.bind(32, &[16], &shapes, BindConfig::default(), 3).unwrap();
        m.fit(&mut iter, &UpdateMode::Local(Arc::new(Sgd::new(0.4))), 3).unwrap();
        let acc_before = m.score(&mut iter).unwrap();
        m.save_params(&p).unwrap();

        // fresh module, load checkpoint: accuracy must carry over
        let model2 = mlp(&[32], 16, 4);
        let mut m2 = Module::new(model2.symbol, engine);
        m2.bind(32, &[16], &shapes, BindConfig::default(), 999).unwrap();
        let acc_fresh = m2.score(&mut iter).unwrap();
        m2.load_params(&p).unwrap();
        let acc_loaded = m2.score(&mut iter).unwrap();
        assert!(acc_loaded > acc_fresh, "{acc_loaded} vs fresh {acc_fresh}");
        assert!((acc_loaded - acc_before).abs() < 1e-6);
        std::fs::remove_file(p).ok();
    }
}

//! Model checkpointing (paper §2.1: *"other functions, such as load,
//! save, memory estimation, and visualization, are also provided"*).
//!
//! A checkpoint is a single binary file holding named f32 tensors with
//! shapes — the parameter side of MXNet's `save_checkpoint` (the symbol
//! side is code in this reproduction, so only parameters serialize).
//!
//! Format (little-endian): magic u32, count u32, then per tensor:
//! name (u32 len + utf8), ndim u32, dims u32*, data f32*.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::engine::EngineRef;
use crate::error::{Error, Result};
use crate::ndarray::NDArray;

/// Checkpoint file magic + version.
pub const CKPT_MAGIC: u32 = 0x6d78_6b01;

/// Train-state checkpoint magic + version (see [`TrainState`]).
pub const TRAIN_CKPT_MAGIC: u32 = 0x6d78_6b02;

/// Everything a [`DataParallelTrainer`](crate::module::DataParallelTrainer)
/// needs to resume bitwise-identically after a crash: master weights and
/// their round versions, updater (optimizer) state, the global round
/// counter, and — for elastic runs — the membership-event log (weights,
/// active set, applied and pending events).  Parameter-only checkpoints
/// ([`save`]) stay the lightweight serving format; this is the recovery
/// format.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TrainState {
    /// Master weights: (key, shape, data), sorted by key.
    pub params: Vec<(String, Vec<usize>, Vec<f32>)>,
    /// Completed rounds per key, aligned with `params` order.
    pub versions: Vec<(String, u64)>,
    /// Optimizer state blobs ([`Optimizer::export_state`](crate::optimizer::Optimizer)).
    pub updater: Vec<(String, Vec<usize>, Vec<f32>)>,
    /// Global synchronization rounds driven so far.
    pub step: u64,
    /// Epochs fully completed (the resume point for the data iterator).
    pub epochs_done: u64,
    /// Elastic per-replica weights (empty for static policies).
    pub weights_cfg: Vec<u32>,
    /// Elastic active set (empty for static policies).
    pub active: Vec<bool>,
    /// Membership events already applied: (round, device, join).
    pub applied_events: Vec<(u64, u32, u8)>,
    /// Membership events queued but not yet due: (round, device, join).
    pub pending_events: Vec<(u64, u32, u8)>,
}

/// Write `bytes` to `path` atomically: a temp file in the same
/// directory is written, synced, and renamed over the target.  A crash
/// (or kill -9) mid-save therefore never truncates the previous good
/// checkpoint — the exact fault the crash-recovery path depends on.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    if let Err(e) = std::fs::rename(&tmp, path) {
        std::fs::remove_file(&tmp).ok();
        return Err(e.into());
    }
    Ok(())
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_blobs(buf: &mut Vec<u8>, blobs: &[(String, Vec<usize>, Vec<f32>)]) {
    put_u32(buf, blobs.len() as u32);
    for (name, shape, data) in blobs {
        put_str(buf, name);
        put_u32(buf, shape.len() as u32);
        for &d in shape {
            put_u32(buf, d as u32);
        }
        put_u32(buf, data.len() as u32);
        for x in data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn put_events(buf: &mut Vec<u8>, evs: &[(u64, u32, u8)]) {
    put_u32(buf, evs.len() as u32);
    for &(round, device, join) in evs {
        put_u64(buf, round);
        put_u32(buf, device);
        buf.push(join);
    }
}

/// Serialize a [`TrainState`] to `path` (little-endian, deterministic
/// byte stream for identical state).
pub fn save_train_state(path: impl AsRef<Path>, st: &TrainState) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    put_u32(&mut buf, TRAIN_CKPT_MAGIC);
    put_blobs(&mut buf, &st.params);
    put_u32(&mut buf, st.versions.len() as u32);
    for (name, v) in &st.versions {
        put_str(&mut buf, name);
        put_u64(&mut buf, *v);
    }
    put_blobs(&mut buf, &st.updater);
    put_u64(&mut buf, st.step);
    put_u64(&mut buf, st.epochs_done);
    put_u32(&mut buf, st.weights_cfg.len() as u32);
    for &w in &st.weights_cfg {
        put_u32(&mut buf, w);
    }
    put_u32(&mut buf, st.active.len() as u32);
    for &a in &st.active {
        buf.push(u8::from(a));
    }
    put_events(&mut buf, &st.applied_events);
    put_events(&mut buf, &st.pending_events);
    write_atomic(path.as_ref(), &buf)
}

struct TrainCursor {
    bytes: Vec<u8>,
    pos: usize,
}

impl TrainCursor {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if n > self.bytes.len() - self.pos {
            return Err(Error::DataIo("train checkpoint: truncated".into()));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A declared count, sanity-bounded by the bytes actually remaining
    /// (`per` bytes per element) so a corrupt header cannot drive a huge
    /// allocation.
    fn count(&mut self, per: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(per.max(1)) > self.bytes.len() - self.pos {
            return Err(Error::DataIo("train checkpoint: count exceeds file size".into()));
        }
        Ok(n)
    }

    fn string(&mut self) -> Result<String> {
        let n = self.count(1)?;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::DataIo("train checkpoint: bad utf8".into()))
    }

    fn blobs(&mut self) -> Result<Vec<(String, Vec<usize>, Vec<f32>)>> {
        let n = self.count(12)?; // minimum bytes per empty blob
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let name = self.string()?;
            let ndim = self.count(4)?;
            if ndim > 8 {
                return Err(Error::DataIo(format!("train checkpoint: ndim {ndim} too large")));
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(self.u32()? as usize);
            }
            let len = self.count(4)?;
            let raw = self.take(len * 4)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            out.push((name, shape, data));
        }
        Ok(out)
    }

    fn events(&mut self) -> Result<Vec<(u64, u32, u8)>> {
        let n = self.count(13)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let round = self.u64()?;
            let device = self.u32()?;
            let join = self.u8()?;
            out.push((round, device, join));
        }
        Ok(out)
    }
}

/// Load a [`TrainState`] previously written by [`save_train_state`].
pub fn load_train_state(path: impl AsRef<Path>) -> Result<TrainState> {
    let mut bytes = Vec::new();
    std::fs::File::open(&path)?.read_to_end(&mut bytes)?;
    let mut c = TrainCursor { bytes, pos: 0 };
    if c.u32()? != TRAIN_CKPT_MAGIC {
        return Err(Error::DataIo("train checkpoint: bad magic".into()));
    }
    let params = c.blobs()?;
    let nvers = c.count(12)?;
    let mut versions = Vec::with_capacity(nvers);
    for _ in 0..nvers {
        let name = c.string()?;
        let v = c.u64()?;
        versions.push((name, v));
    }
    let updater = c.blobs()?;
    let step = c.u64()?;
    let epochs_done = c.u64()?;
    let nw = c.count(4)?;
    let mut weights_cfg = Vec::with_capacity(nw);
    for _ in 0..nw {
        weights_cfg.push(c.u32()?);
    }
    let na = c.count(1)?;
    let mut active = Vec::with_capacity(na);
    for _ in 0..na {
        active.push(c.u8()? != 0);
    }
    let applied_events = c.events()?;
    let pending_events = c.events()?;
    if c.pos != c.bytes.len() {
        return Err(Error::DataIo("train checkpoint: trailing bytes".into()));
    }
    Ok(TrainState {
        params,
        versions,
        updater,
        step,
        epochs_done,
        weights_cfg,
        active,
        applied_events,
        pending_events,
    })
}

/// Save named arrays to `path` (sorted by name for determinism).
pub fn save(path: impl AsRef<Path>, params: &HashMap<String, NDArray>) -> Result<()> {
    let mut names: Vec<&String> = params.keys().collect();
    names.sort();
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(&CKPT_MAGIC.to_le_bytes());
    buf.extend_from_slice(&(names.len() as u32).to_le_bytes());
    for name in names {
        let arr = &params[name];
        let data = arr.to_vec(); // waits for pending engine ops
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(&(arr.shape().len() as u32).to_le_bytes());
        for &d in arr.shape() {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for x in &data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    write_atomic(path.as_ref(), &buf)
}

/// Load a checkpoint into new arrays on `engine`.
pub fn load(path: impl AsRef<Path>, engine: EngineRef) -> Result<HashMap<String, NDArray>> {
    let mut bytes = Vec::new();
    std::fs::File::open(&path)?.read_to_end(&mut bytes)?;
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            return Err(Error::DataIo("checkpoint: truncated".into()));
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let u32_at = |pos: &mut usize| -> Result<u32> {
        Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
    };
    if u32_at(&mut pos)? != CKPT_MAGIC {
        return Err(Error::DataIo("checkpoint: bad magic".into()));
    }
    let count = u32_at(&mut pos)? as usize;
    let mut out = HashMap::with_capacity(count);
    for _ in 0..count {
        let nlen = u32_at(&mut pos)? as usize;
        let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())
            .map_err(|_| Error::DataIo("checkpoint: bad utf8 name".into()))?;
        let ndim = u32_at(&mut pos)? as usize;
        if ndim > 8 {
            return Err(Error::DataIo(format!("checkpoint: ndim {ndim} too large")));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32_at(&mut pos)? as usize);
        }
        let size: usize = shape.iter().product();
        let raw = take(&mut pos, size * 4)?;
        let data: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        out.insert(name, NDArray::from_vec_on(&shape, data, engine.clone()));
    }
    if pos != bytes.len() {
        return Err(Error::DataIo("checkpoint: trailing bytes".into()));
    }
    Ok(out)
}

impl crate::module::Module {
    /// Save this module's parameters (paper's `save_checkpoint`).
    pub fn save_params(&self, path: impl AsRef<Path>) -> Result<()> {
        let map: HashMap<String, NDArray> = self
            .param_names()
            .iter()
            .map(|n| (n.clone(), self.param(n).unwrap().clone()))
            .collect();
        save(path, &map)
    }

    /// Overwrite this module's parameters from a checkpoint (must be
    /// bound; shapes must match).
    pub fn load_params(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let engine = self.param(self.param_names().first().ok_or_else(|| {
            Error::Bind("module has no parameters (bind first)".into())
        })?).unwrap().engine();
        let loaded = load(path, engine)?;
        for name in self.param_names().to_vec() {
            let src = loaded.get(&name).ok_or_else(|| {
                Error::DataIo(format!("checkpoint missing parameter '{name}'"))
            })?;
            let dst = self.param(&name).unwrap();
            if dst.shape() != src.shape() {
                return Err(Error::DataIo(format!(
                    "checkpoint '{name}': shape {:?} != bound {:?}",
                    src.shape(),
                    dst.shape()
                )));
            }
            dst.copy_from_(src);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::default_engine;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mixnet_ckpt_{}_{tag}.bin", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_names_shapes_values() {
        let p = tmp("rt");
        let mut m = HashMap::new();
        m.insert("w".to_string(), NDArray::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 7.0, -0.25]));
        m.insert("b".to_string(), NDArray::from_vec(&[3], vec![0.1, 0.2, 0.3]));
        save(&p, &m).unwrap();
        let back = load(&p, default_engine()).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["w"].shape(), &[2, 3]);
        assert_eq!(back["w"].to_vec(), m["w"].to_vec());
        assert_eq!(back["b"].to_vec(), m["b"].to_vec());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corrupt_magic_rejected() {
        let p = tmp("magic");
        save(&p, &HashMap::new()).unwrap();
        let mut b = std::fs::read(&p).unwrap();
        b[0] ^= 0xff;
        std::fs::write(&p, b).unwrap();
        assert!(load(&p, default_engine()).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn truncation_rejected() {
        let p = tmp("trunc");
        let mut m = HashMap::new();
        m.insert("w".to_string(), NDArray::from_vec(&[64], vec![1.0; 64]));
        save(&p, &m).unwrap();
        let b = std::fs::read(&p).unwrap();
        std::fs::write(&p, &b[..b.len() - 10]).unwrap();
        assert!(load(&p, default_engine()).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn train_state_roundtrips_exactly() {
        let p = tmp("train_rt");
        let st = TrainState {
            params: vec![
                ("b".into(), vec![3], vec![0.1, -0.2, f32::MIN_POSITIVE]),
                ("w".into(), vec![2, 2], vec![1.0, 2.0, -3.5, 4.25]),
            ],
            versions: vec![("b".into(), 17), ("w".into(), 17)],
            updater: vec![("vel:w".into(), vec![2, 2], vec![0.0, -0.5, 0.25, 1e-8])],
            step: 17,
            epochs_done: 2,
            weights_cfg: vec![2, 1, 1],
            active: vec![true, false, true],
            applied_events: vec![(5, 1, 0)],
            pending_events: vec![(40, 1, 1)],
        };
        save_train_state(&p, &st).unwrap();
        let back = load_train_state(&p).unwrap();
        assert_eq!(back, st);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn train_state_rejects_corruption() {
        let p = tmp("train_bad");
        let st = TrainState {
            params: vec![("w".into(), vec![4], vec![1.0; 4])],
            versions: vec![("w".into(), 1)],
            step: 1,
            ..TrainState::default()
        };
        save_train_state(&p, &st).unwrap();
        let good = std::fs::read(&p).unwrap();
        // bad magic
        let mut b = good.clone();
        b[0] ^= 0xff;
        std::fs::write(&p, &b).unwrap();
        assert!(load_train_state(&p).is_err());
        // truncation at every prefix must error, never panic
        for cut in [4usize, 8, 20, good.len() - 3] {
            std::fs::write(&p, &good[..cut]).unwrap();
            assert!(load_train_state(&p).is_err(), "cut at {cut}");
        }
        // a count field inflated past the file size must be rejected
        // before allocation (params count lives right after the magic)
        let mut b = good.clone();
        b[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        assert!(load_train_state(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    /// Saves go through a temp-file + rename, so overwriting an existing
    /// checkpoint can never truncate it in place (a crash mid-save
    /// leaves the previous good file), and stale temp files from a
    /// crashed earlier save are harmless.
    #[test]
    fn save_train_state_is_atomic_overwrite() {
        let p = tmp("atomic");
        let mut tmp_name = p.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp_path = std::path::PathBuf::from(tmp_name);
        let a = TrainState {
            params: vec![("w".into(), vec![2], vec![1.0, 2.0])],
            versions: vec![("w".into(), 1)],
            step: 1,
            ..TrainState::default()
        };
        save_train_state(&p, &a).unwrap();
        // a stale temp file left by a crashed save must not interfere
        std::fs::write(&tmp_path, b"garbage from a crashed save").unwrap();
        let b = TrainState { step: 2, epochs_done: 1, ..a.clone() };
        save_train_state(&p, &b).unwrap();
        assert_eq!(load_train_state(&p).unwrap(), b);
        assert!(!tmp_path.exists(), "temp file must be renamed over the target");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn module_save_load_resumes_training() {
        use crate::executor::BindConfig;
        use crate::io::{synth::class_clusters, ArrayDataIter};
        use crate::models::mlp;
        use crate::module::{Module, UpdateMode};
        use crate::optimizer::Sgd;
        use std::sync::Arc;

        let p = tmp("resume");
        let engine = crate::engine::create(crate::engine::EngineKind::Threaded, 2);
        let ds = class_clusters(256, 4, 16, 0.3, 9);
        let mut iter =
            ArrayDataIter::new(ds.features, ds.labels, &[16], 32, true, engine.clone());
        let model = mlp(&[32], 16, 4);
        let shapes = model.param_shapes(32).unwrap();
        let mut m = Module::new(model.symbol, engine.clone());
        m.bind(32, &[16], &shapes, BindConfig::default(), 3).unwrap();
        m.fit(&mut iter, &UpdateMode::Local(Arc::new(Sgd::new(0.4))), 3).unwrap();
        let acc_before = m.score(&mut iter).unwrap();
        m.save_params(&p).unwrap();

        // fresh module, load checkpoint: accuracy must carry over
        let model2 = mlp(&[32], 16, 4);
        let mut m2 = Module::new(model2.symbol, engine);
        m2.bind(32, &[16], &shapes, BindConfig::default(), 999).unwrap();
        let acc_fresh = m2.score(&mut iter).unwrap();
        m2.load_params(&p).unwrap();
        let acc_loaded = m2.score(&mut iter).unwrap();
        assert!(acc_loaded > acc_fresh, "{acc_loaded} vs fresh {acc_fresh}");
        assert!((acc_loaded - acc_before).abs() < 1e-6);
        std::fs::remove_file(p).ok();
    }
}

//! RecordIO — the paper's packed example format (§2.4: *"tools to pack
//! arbitrary sized examples into a single compact file to facilitate both
//! sequential and random seek"*).
//!
//! Layout per record: `MAGIC u32 | len u32 | payload | pad to 4 bytes`.
//! A writer returns the byte offset of every record, forming the index
//! that enables random seek (shuffled epochs without loading the file).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::error::{Error, Result};

/// Record delimiter magic.
pub const MAGIC: u32 = 0xced7_230a;

/// Sequential writer; collects the seek index.
pub struct RecordWriter {
    out: BufWriter<File>,
    offsets: Vec<u64>,
    pos: u64,
}

impl RecordWriter {
    /// Create/truncate `path`.
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        Ok(RecordWriter { out: BufWriter::new(File::create(path)?), offsets: vec![], pos: 0 })
    }

    /// Append one record; returns its index.
    pub fn write_record(&mut self, payload: &[u8]) -> Result<usize> {
        self.offsets.push(self.pos);
        self.out.write_all(&MAGIC.to_le_bytes())?;
        self.out.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.out.write_all(payload)?;
        let pad = (4 - payload.len() % 4) % 4;
        self.out.write_all(&[0u8; 3][..pad])?;
        self.pos += 8 + payload.len() as u64 + pad as u64;
        Ok(self.offsets.len() - 1)
    }

    /// Flush and return the record index (offsets).
    pub fn finish(mut self) -> Result<Vec<u64>> {
        self.out.flush()?;
        Ok(self.offsets)
    }
}

/// Reader supporting sequential scan and random seek.
pub struct RecordReader {
    input: BufReader<File>,
}

impl RecordReader {
    /// Open `path`.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        Ok(RecordReader { input: BufReader::new(File::open(path)?) })
    }

    /// Read the next record, or `None` at EOF.
    pub fn next_record(&mut self) -> Result<Option<Vec<u8>>> {
        let mut hdr = [0u8; 8];
        match self.input.read_exact(&mut hdr[..4]) {
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            r => r?,
        }
        self.input.read_exact(&mut hdr[4..])?;
        let magic = u32::from_le_bytes(hdr[..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(Error::DataIo(format!("bad magic {magic:#x}")));
        }
        let len = u32::from_le_bytes(hdr[4..].try_into().unwrap()) as usize;
        let mut payload = vec![0u8; len];
        self.input.read_exact(&mut payload)?;
        let pad = (4 - len % 4) % 4;
        if pad > 0 {
            let mut p = [0u8; 3];
            self.input.read_exact(&mut p[..pad])?;
        }
        Ok(Some(payload))
    }

    /// Random seek to a record offset (from the writer's index).
    pub fn seek_record(&mut self, offset: u64) -> Result<Option<Vec<u8>>> {
        self.input.seek(SeekFrom::Start(offset))?;
        self.next_record()
    }
}

/// A labelled f32 example, the payload our datasets pack into records.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    /// Class label (or regression target).
    pub label: f32,
    /// Feature dims.
    pub shape: Vec<usize>,
    /// Row-major features.
    pub data: Vec<f32>,
}

impl Example {
    /// Serialize to a record payload.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 4 * self.shape.len() + 4 * self.data.len());
        out.extend_from_slice(&self.label.to_le_bytes());
        out.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for &d in &self.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Deserialize from a record payload.
    pub fn from_bytes(b: &[u8]) -> Result<Example> {
        let need = |n: usize| {
            if b.len() < n {
                Err(Error::DataIo(format!("example truncated at {n}")))
            } else {
                Ok(())
            }
        };
        need(8)?;
        let label = f32::from_le_bytes(b[0..4].try_into().unwrap());
        let ndim = u32::from_le_bytes(b[4..8].try_into().unwrap()) as usize;
        need(8 + 4 * ndim)?;
        let mut shape = Vec::with_capacity(ndim);
        for i in 0..ndim {
            shape.push(u32::from_le_bytes(b[8 + 4 * i..12 + 4 * i].try_into().unwrap()) as usize);
        }
        let size: usize = shape.iter().product();
        let off = 8 + 4 * ndim;
        need(off + 4 * size)?;
        let data = (0..size)
            .map(|i| f32::from_le_bytes(b[off + 4 * i..off + 4 * i + 4].try_into().unwrap()))
            .collect();
        Ok(Example { label, shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("mixnet_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_sequential() {
        let path = tmp("seq.rec");
        let mut w = RecordWriter::create(&path).unwrap();
        for i in 0..10u32 {
            w.write_record(&i.to_le_bytes()).unwrap();
        }
        let idx = w.finish().unwrap();
        assert_eq!(idx.len(), 10);
        let mut r = RecordReader::open(&path).unwrap();
        for i in 0..10u32 {
            let rec = r.next_record().unwrap().unwrap();
            assert_eq!(rec, i.to_le_bytes());
        }
        assert!(r.next_record().unwrap().is_none());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn random_seek_via_index() {
        let path = tmp("seek.rec");
        let mut w = RecordWriter::create(&path).unwrap();
        // variable-size payloads to exercise padding
        for i in 0..20usize {
            let payload = vec![i as u8; i + 1];
            w.write_record(&payload).unwrap();
        }
        let idx = w.finish().unwrap();
        let mut r = RecordReader::open(&path).unwrap();
        for &i in &[7usize, 0, 19, 3] {
            let rec = r.seek_record(idx[i]).unwrap().unwrap();
            assert_eq!(rec, vec![i as u8; i + 1]);
        }
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn corrupt_magic_detected() {
        let path = tmp("bad.rec");
        std::fs::write(&path, [0u8; 16]).unwrap();
        let mut r = RecordReader::open(&path).unwrap();
        assert!(r.next_record().is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn example_roundtrip() {
        let ex = Example { label: 3.0, shape: vec![2, 3], data: (0..6).map(|v| v as f32).collect() };
        let back = Example::from_bytes(&ex.to_bytes()).unwrap();
        assert_eq!(ex, back);
    }

    #[test]
    fn truncated_example_errors() {
        let ex = Example { label: 1.0, shape: vec![4], data: vec![1.0; 4] };
        let bytes = ex.to_bytes();
        assert!(Example::from_bytes(&bytes[..bytes.len() - 2]).is_err());
    }
}

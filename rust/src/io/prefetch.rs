//! Multi-threaded batch prefetching (paper §2.4: *"data pre-fetching and
//! pre-processing are multi-threaded, reducing overheads due to possible
//! remote file store reads and/or image decoding"*).
//!
//! Wraps any [`DataIter`] with a background producer thread and a bounded
//! channel, so batch decode overlaps training compute.
//!
//! Epoch protocol: every queued item carries the producer's epoch number
//! and every [`reset`](PrefetchIter::reset) bumps the consumer's expected
//! epoch, so stale in-flight batches from before a rewind are skipped
//! exactly — no heuristics about what might still be buffered.
//!
//! The in-flight depth defaults from the `PALLAS_PREFETCH_DEPTH`
//! environment knob (see [`PrefetchIter::default_depth`]).

use std::sync::mpsc;
use std::thread::JoinHandle;

use super::{DataBatch, DataIter};

enum Ctl {
    Reset,
    Stop,
}

/// Background-prefetching wrapper around a [`DataIter`].
pub struct PrefetchIter {
    batch_rx: mpsc::Receiver<(u64, Option<DataBatch>)>,
    ctl_tx: mpsc::Sender<Ctl>,
    worker: Option<JoinHandle<()>>,
    batch: usize,
    /// Epoch the consumer expects; items tagged lower are stale.
    want_epoch: u64,
}

impl PrefetchIter {
    /// Default in-flight depth: the `PALLAS_PREFETCH_DEPTH` environment
    /// knob, falling back to 3 (enough to hide one slow decode behind
    /// two compute-bound steps without hoarding batch memory).
    pub fn default_depth() -> usize {
        std::env::var("PALLAS_PREFETCH_DEPTH")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&d| d >= 1)
            .unwrap_or(3)
    }

    /// Wrap `inner` with the env-configured depth
    /// ([`PrefetchIter::default_depth`]).
    pub fn with_default_depth(inner: Box<dyn DataIter>) -> Self {
        Self::new(inner, Self::default_depth())
    }

    /// Wrap `inner`, keeping up to `depth` decoded batches in flight.
    pub fn new(mut inner: Box<dyn DataIter>, depth: usize) -> Self {
        let batch = inner.batch_size();
        let (batch_tx, batch_rx) = mpsc::sync_channel::<(u64, Option<DataBatch>)>(depth.max(1));
        let (ctl_tx, ctl_rx) = mpsc::channel::<Ctl>();
        let worker = std::thread::Builder::new()
            .name("mixnet-prefetch".into())
            .spawn(move || {
                let mut epoch = 0u64;
                loop {
                    // apply any pending control first
                    loop {
                        match ctl_rx.try_recv() {
                            Ok(Ctl::Reset) => {
                                inner.reset();
                                epoch += 1;
                            }
                            Ok(Ctl::Stop) | Err(mpsc::TryRecvError::Disconnected) => return,
                            Err(mpsc::TryRecvError::Empty) => break,
                        }
                    }
                    let item = inner.next_batch();
                    let at_end = item.is_none();
                    if batch_tx.send((epoch, item)).is_err() {
                        return;
                    }
                    if at_end {
                        // park until a reset or stop arrives
                        match ctl_rx.recv() {
                            Ok(Ctl::Reset) => {
                                inner.reset();
                                epoch += 1;
                            }
                            Ok(Ctl::Stop) | Err(_) => return,
                        }
                    }
                }
            })
            .expect("spawn prefetch");
        PrefetchIter { batch_rx, ctl_tx, worker: Some(worker), batch, want_epoch: 0 }
    }
}

impl DataIter for PrefetchIter {
    fn next_batch(&mut self) -> Option<DataBatch> {
        // The span measures how long the consumer blocked on the
        // prefetch channel — the data-starvation signal in a trace.
        let prof = crate::profile::SpanTimer::start();
        let out = loop {
            let Ok((epoch, item)) = self.batch_rx.recv() else { break None };
            if epoch < self.want_epoch {
                continue; // stale: produced before the rewind we requested
            }
            break item;
        };
        prof.finish(crate::profile::Category::Io, "io.prefetch_wait", 0, 0, 0);
        out
    }

    fn reset(&mut self) {
        let _ = self.ctl_tx.send(Ctl::Reset);
        self.want_epoch += 1;
    }

    fn batch_size(&self) -> usize {
        self.batch
    }
}

impl Drop for PrefetchIter {
    fn drop(&mut self) {
        let _ = self.ctl_tx.send(Ctl::Stop);
        // Unblock a producer stuck on a full channel.
        while self.batch_rx.try_recv().is_ok() {}
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::default_engine;
    use crate::io::ArrayDataIter;

    fn small_iter(n: usize, batch: usize) -> Box<dyn DataIter> {
        let feats: Vec<f32> = (0..n).map(|v| v as f32).collect();
        let labels = feats.clone();
        Box::new(ArrayDataIter::new(feats, labels, &[1], batch, false, default_engine()))
    }

    #[test]
    fn yields_same_batches_as_inner() {
        let mut plain = small_iter(12, 4);
        let mut pre = PrefetchIter::new(small_iter(12, 4), 2);
        loop {
            let a = plain.next_batch();
            let b = pre.next_batch();
            match (a, b) {
                (None, None) => break,
                (Some(x), Some(y)) => {
                    assert_eq!(x.label.to_vec(), y.label.to_vec());
                }
                _ => panic!("length mismatch"),
            }
        }
    }

    #[test]
    fn reset_restarts_epoch() {
        let mut pre = PrefetchIter::new(small_iter(8, 4), 2);
        let first = pre.next_batch().unwrap().label.to_vec();
        // consume rest of epoch
        while pre.next_batch().is_some() {}
        pre.reset();
        let again = pre.next_batch().unwrap().label.to_vec();
        assert_eq!(first, again);
    }

    #[test]
    fn mid_epoch_reset_drains_stale_batches() {
        let mut pre = PrefetchIter::new(small_iter(32, 4), 4);
        let first = pre.next_batch().unwrap().label.to_vec();
        pre.reset(); // stale prefetched batches must be discarded
        let again = pre.next_batch().unwrap().label.to_vec();
        assert_eq!(first, again, "after reset the epoch restarts");
    }

    #[test]
    fn reset_before_first_batch_is_safe() {
        // The fit() loop resets at every epoch start, including the first,
        // possibly before the producer has emitted anything.
        let mut pre = PrefetchIter::new(small_iter(8, 4), 2);
        pre.reset();
        let mut n = 0;
        while pre.next_batch().is_some() {
            n += 1;
        }
        assert_eq!(n, 2, "epoch after immediate reset must be complete");
    }

    #[test]
    fn many_epochs_like_fit() {
        // Exactly the fit() access pattern: reset, drain, repeat.
        let mut pre = PrefetchIter::new(small_iter(16, 4), 3);
        for _ in 0..5 {
            pre.reset();
            let mut n = 0;
            while pre.next_batch().is_some() {
                n += 1;
            }
            assert_eq!(n, 4);
        }
    }

    #[test]
    fn drop_while_producer_blocked_does_not_hang() {
        let pre = PrefetchIter::new(small_iter(1000, 4), 1);
        drop(pre); // must not deadlock
    }

    #[test]
    fn env_depth_default_and_wrapper() {
        // Without the env knob set the default is 3; with it set another
        // test process would see that value — here we only assert the
        // invariants that hold either way.
        assert!(PrefetchIter::default_depth() >= 1);
        let mut pre = PrefetchIter::with_default_depth(small_iter(8, 4));
        let mut n = 0;
        while pre.next_batch().is_some() {
            n += 1;
        }
        assert_eq!(n, 2);
    }
}

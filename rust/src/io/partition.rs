//! Deterministic batch partitioning for data-parallel training
//! (paper §2.3: each device computes the gradient on its slice of the
//! minibatch).
//!
//! [`PartitionIter`] wraps any [`DataIter`] and splits every global batch
//! into `shards` contiguous sub-batches ("device shards").  The split is
//! a pure function of the batch contents and the shard count — example
//! blocks are assigned in order, sizes differing by at most one — so the
//! decomposition is stable across runs, thread counts and *device*
//! counts: the data-parallel trainer fixes the shard count and lets the
//! number of replicas vary, which is what makes its results bitwise
//! invariant to how many devices consume the shards.

use std::collections::VecDeque;

use crate::ndarray::NDArray;

use super::{DataBatch, DataIter};

/// The canonical shard geometry: contiguous `(row offset, row count)`
/// ranges splitting `rows` into `shards` parts.
///
/// With `rows = q*shards + r`, the first `r` shards get `q + 1` rows and
/// the rest get `q` (sizes differ by at most one); empty ranges
/// (`rows < shards`) are omitted.  This is the single source of truth
/// for shard assignment — [`split_batch`] materializes these ranges as
/// sub-batches, and the data-parallel trainer copies the same ranges
/// straight into its replica buffers (no intermediate arrays on the hot
/// path) — so both views of a batch are bitwise identical by
/// construction.
pub fn shard_ranges(rows: usize, shards: usize) -> Vec<(usize, usize)> {
    assert!(shards >= 1, "shard_ranges: shards must be >= 1");
    let (q, r) = (rows / shards, rows % shards);
    let mut out = Vec::with_capacity(shards.min(rows));
    let mut off = 0usize;
    for s in 0..shards {
        let n = q + usize::from(s < r);
        if n == 0 {
            continue;
        }
        out.push((off, n));
        off += n;
    }
    out
}

/// Split one batch into `shards` contiguous sub-batches (see
/// [`shard_ranges`] for the geometry; the returned vector has
/// `min(shards, rows)` entries).
pub fn split_batch(batch: &DataBatch, shards: usize) -> Vec<DataBatch> {
    let rows = batch.data.shape()[0];
    debug_assert_eq!(rows, batch.label.size(), "data/label row mismatch");
    let feat: usize = batch.data.shape()[1..].iter().product();
    let data = batch.data.to_vec();
    let label = batch.label.to_vec();
    let engine = batch.data.engine();
    shard_ranges(rows, shards)
        .into_iter()
        .map(|(off, n)| {
            let mut shape = vec![n];
            shape.extend_from_slice(&batch.data.shape()[1..]);
            let d = data[off * feat..(off + n) * feat].to_vec();
            let l = label[off..off + n].to_vec();
            DataBatch {
                data: NDArray::from_vec_on(&shape, d, engine.clone()),
                label: NDArray::from_vec_on(&[n], l, engine.clone()),
            }
        })
        .collect()
}

/// Iterator adapter yielding per-device shards of an inner iterator's
/// batches (see the module docs).
///
/// Use [`PartitionIter::next_shards`] to get one round's shard group at
/// a time (what the trainer consumes), or the [`DataIter`] impl to
/// stream the same shards one by one in shard order.
pub struct PartitionIter<'a> {
    inner: &'a mut dyn DataIter,
    shards: usize,
    queue: VecDeque<DataBatch>,
}

impl<'a> PartitionIter<'a> {
    /// Wrap `inner`, splitting each of its batches into `shards` parts.
    pub fn new(inner: &'a mut dyn DataIter, shards: usize) -> Self {
        assert!(shards >= 1, "PartitionIter: shards must be >= 1");
        PartitionIter { inner, shards, queue: VecDeque::new() }
    }

    /// The configured shard count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The next global batch, split into shards (at most `shards`
    /// entries; fewer when the batch has fewer rows than shards).
    /// `None` at epoch end.
    pub fn next_shards(&mut self) -> Option<Vec<DataBatch>> {
        let b = self.inner.next_batch()?;
        Some(split_batch(&b, self.shards))
    }
}

impl DataIter for PartitionIter<'_> {
    fn next_batch(&mut self) -> Option<DataBatch> {
        if self.queue.is_empty() {
            let group = self.next_shards()?;
            self.queue.extend(group);
        }
        self.queue.pop_front()
    }

    fn reset(&mut self) {
        self.queue.clear();
        self.inner.reset();
    }

    fn batch_size(&self) -> usize {
        // largest shard size (the first shards get the remainder rows)
        self.inner.batch_size().div_ceil(self.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::default_engine;
    use crate::io::ArrayDataIter;

    fn iter(n: usize, batch: usize) -> ArrayDataIter {
        let feats: Vec<f32> = (0..n * 2).map(|v| v as f32).collect();
        let labels: Vec<f32> = (0..n).map(|v| v as f32).collect();
        ArrayDataIter::new(feats, labels, &[2], batch, false, default_engine())
    }

    #[test]
    fn even_split_preserves_rows_in_order() {
        let mut it = iter(8, 8);
        let mut p = PartitionIter::new(&mut it, 4);
        let shards = p.next_shards().unwrap();
        assert_eq!(shards.len(), 4);
        let mut labels = Vec::new();
        for s in &shards {
            assert_eq!(s.data.shape(), &[2, 2]);
            assert_eq!(s.label.size(), 2);
            labels.extend(s.label.to_vec());
        }
        assert_eq!(labels, (0..8).map(|v| v as f32).collect::<Vec<_>>());
    }

    #[test]
    fn uneven_split_sizes_differ_by_at_most_one() {
        // 10 rows over 4 shards -> [3, 3, 2, 2]
        let mut it = iter(10, 10);
        let mut p = PartitionIter::new(&mut it, 4);
        let shards = p.next_shards().unwrap();
        let sizes: Vec<usize> = shards.iter().map(|s| s.label.size()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        // contiguous coverage, no row lost or duplicated
        let all: Vec<f32> = shards.iter().flat_map(|s| s.label.to_vec()).collect();
        assert_eq!(all, (0..10).map(|v| v as f32).collect::<Vec<_>>());
        // features travel with their rows
        assert_eq!(shards[1].data.to_vec()[0], 6.0, "row 3 starts at feature 6");
    }

    #[test]
    fn shard_ranges_geometry() {
        assert_eq!(shard_ranges(8, 4), vec![(0, 2), (2, 2), (4, 2), (6, 2)]);
        assert_eq!(shard_ranges(10, 4), vec![(0, 3), (3, 3), (6, 2), (8, 2)]);
        assert_eq!(shard_ranges(2, 4), vec![(0, 1), (1, 1)]);
        assert_eq!(shard_ranges(5, 1), vec![(0, 5)]);
        // covers exactly, in order
        for (rows, shards) in [(17usize, 5usize), (64, 8), (3, 7)] {
            let rs = shard_ranges(rows, shards);
            let mut expect = 0;
            for (off, n) in rs {
                assert_eq!(off, expect);
                assert!(n >= 1);
                expect += n;
            }
            assert_eq!(expect, rows);
        }
    }

    #[test]
    fn tiny_batch_omits_empty_shards() {
        let mut it = iter(2, 2);
        let mut p = PartitionIter::new(&mut it, 4);
        let shards = p.next_shards().unwrap();
        assert_eq!(shards.len(), 2);
        assert!(shards.iter().all(|s| s.label.size() == 1));
    }

    #[test]
    fn deterministic_across_instances() {
        for _ in 0..2 {
            let mut a = iter(12, 6);
            let mut b = iter(12, 6);
            let mut pa = PartitionIter::new(&mut a, 3);
            let mut pb = PartitionIter::new(&mut b, 3);
            while let (Some(ga), Some(gb)) = (pa.next_shards(), pb.next_shards()) {
                for (x, y) in ga.iter().zip(&gb) {
                    assert_eq!(x.data.to_vec(), y.data.to_vec());
                    assert_eq!(x.label.to_vec(), y.label.to_vec());
                }
            }
        }
    }

    #[test]
    fn data_iter_impl_flattens_shards_in_order() {
        let mut plain = iter(8, 4);
        let mut sharded = iter(8, 4);
        let mut p = PartitionIter::new(&mut sharded, 2);
        assert_eq!(p.batch_size(), 2);
        let mut flat = Vec::new();
        while let Some(b) = p.next_batch() {
            assert_eq!(b.label.size(), 2);
            flat.extend(b.label.to_vec());
        }
        let mut expect = Vec::new();
        while let Some(b) = plain.next_batch() {
            expect.extend(b.label.to_vec());
        }
        assert_eq!(flat, expect, "shards concatenate back to the inner stream");
        // reset restarts cleanly
        p.reset();
        assert_eq!(p.next_batch().unwrap().label.to_vec(), vec![0.0, 1.0]);
    }
}

//! Deterministic batch partitioning for data-parallel training
//! (paper §2.3: each device computes the gradient on its slice of the
//! minibatch).
//!
//! [`PartitionIter`] wraps any [`DataIter`] and splits every global batch
//! into `shards` contiguous sub-batches ("device shards").  The split is
//! a pure function of the batch contents and the shard count — example
//! blocks are assigned in order, sizes differing by at most one — so the
//! decomposition is stable across runs, thread counts and *device*
//! counts: the data-parallel trainer fixes the shard count and lets the
//! number of replicas vary, which is what makes its results bitwise
//! invariant to how many devices consume the shards.

use std::collections::VecDeque;

use crate::ndarray::NDArray;

use super::{DataBatch, DataIter};

/// The canonical shard geometry: contiguous `(row offset, row count)`
/// ranges splitting `rows` into `shards` parts.
///
/// With `rows = q*shards + r`, the first `r` shards get `q + 1` rows and
/// the rest get `q` (sizes differ by at most one); empty ranges
/// (`rows < shards`) are omitted.  This is the single source of truth
/// for shard assignment — [`split_batch`] materializes these ranges as
/// sub-batches, and the data-parallel trainer copies the same ranges
/// straight into its replica buffers (no intermediate arrays on the hot
/// path) — so both views of a batch are bitwise identical by
/// construction.
pub fn shard_ranges(rows: usize, shards: usize) -> Vec<(usize, usize)> {
    assert!(shards >= 1, "shard_ranges: shards must be >= 1");
    let (q, r) = (rows / shards, rows % shards);
    let mut out = Vec::with_capacity(shards.min(rows));
    let mut off = 0usize;
    for s in 0..shards {
        let n = q + usize::from(s < r);
        if n == 0 {
            continue;
        }
        out.push((off, n));
        off += n;
    }
    out
}

/// Weighted shard geometry for heterogeneous replicas: contiguous
/// `(row offset, row count)` ranges splitting `rows` proportionally to
/// `weights` — a straggler with weight 1 next to a fast host with
/// weight 3 receives a quarter of the rows.
///
/// Apportionment is largest-remainder (floor `rows·wᵢ/W`, leftover rows
/// to the largest fractional remainders, ties to the lower index), so
/// the split is a pure deterministic function of `(rows, weights)`:
/// counts sum exactly to `rows`, zero-weight entries receive zero rows,
/// and — like [`shard_ranges`] — empty ranges are omitted.  Equal
/// weights reproduce `shard_ranges(rows, weights.len())` exactly.
pub fn shard_ranges_weighted(rows: usize, weights: &[u32]) -> Vec<(usize, usize)> {
    let w64: Vec<u64> = weights.iter().map(|&w| w as u64).collect();
    let counts = largest_remainder_counts(rows, &w64)
        .expect("shard_ranges_weighted: at least one weight must be > 0");
    let mut out = Vec::with_capacity(weights.len());
    let mut off = 0usize;
    for n in counts {
        if n == 0 {
            continue;
        }
        out.push((off, n));
        off += n;
    }
    debug_assert_eq!(off, rows);
    out
}

/// Largest-remainder apportionment of `total` indivisible units over
/// `weights`: each entry gets `floor(total·wᵢ/W)` units, leftover units
/// go to the largest fractional remainders (ties to the lower index).
/// The single deterministic-apportionment primitive behind both
/// [`shard_ranges_weighted`] (batch rows) and the trainer's
/// weight-proportional shard placement
/// ([`crate::module::proportional_parts`]).  Errors when no weight is
/// positive.
pub fn largest_remainder_counts(
    total: usize,
    weights: &[u64],
) -> std::result::Result<Vec<usize>, &'static str> {
    let w_sum: u64 = weights.iter().sum();
    if weights.is_empty() || w_sum == 0 {
        return Err("largest-remainder apportionment needs a weight > 0");
    }
    let mut counts: Vec<usize> = Vec::with_capacity(weights.len());
    let mut rems: Vec<(u64, usize)> = Vec::with_capacity(weights.len());
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let num = total as u64 * w;
        counts.push((num / w_sum) as usize);
        assigned += (num / w_sum) as usize;
        rems.push((num % w_sum, i));
    }
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(rem, i) in rems.iter().take(total - assigned) {
        debug_assert!(rem > 0, "a zero remainder can never win a leftover unit");
        counts[i] += 1;
    }
    debug_assert_eq!(counts.iter().sum::<usize>(), total);
    Ok(counts)
}

/// Split one batch into `shards` contiguous sub-batches (see
/// [`shard_ranges`] for the geometry; the returned vector has
/// `min(shards, rows)` entries).
pub fn split_batch(batch: &DataBatch, shards: usize) -> Vec<DataBatch> {
    materialize_ranges(batch, shard_ranges(batch.data.shape()[0], shards))
}

/// Split one batch along the weighted geometry of
/// [`shard_ranges_weighted`] — the materialized form heterogeneous
/// multi-process workers consume.
pub fn split_batch_weighted(batch: &DataBatch, weights: &[u32]) -> Vec<DataBatch> {
    materialize_ranges(batch, shard_ranges_weighted(batch.data.shape()[0], weights))
}

fn materialize_ranges(batch: &DataBatch, ranges: Vec<(usize, usize)>) -> Vec<DataBatch> {
    let rows = batch.data.shape()[0];
    debug_assert_eq!(rows, batch.label.size(), "data/label row mismatch");
    let feat: usize = batch.data.shape()[1..].iter().product();
    let data = batch.data.to_vec();
    let label = batch.label.to_vec();
    let engine = batch.data.engine();
    ranges
        .into_iter()
        .map(|(off, n)| {
            let mut shape = vec![n];
            shape.extend_from_slice(&batch.data.shape()[1..]);
            let d = data[off * feat..(off + n) * feat].to_vec();
            let l = label[off..off + n].to_vec();
            DataBatch {
                data: NDArray::from_vec_on(&shape, d, engine.clone()),
                label: NDArray::from_vec_on(&[n], l, engine.clone()),
            }
        })
        .collect()
}

/// Iterator adapter yielding per-device shards of an inner iterator's
/// batches (see the module docs).
///
/// Use [`PartitionIter::next_shards`] to get one round's shard group at
/// a time (what the trainer consumes), or the [`DataIter`] impl to
/// stream the same shards one by one in shard order.
pub struct PartitionIter<'a> {
    inner: &'a mut dyn DataIter,
    shards: usize,
    /// Per-shard row weights (`None` = equal split).
    weights: Option<Vec<u32>>,
    queue: VecDeque<DataBatch>,
}

impl<'a> PartitionIter<'a> {
    /// Wrap `inner`, splitting each of its batches into `shards` parts.
    pub fn new(inner: &'a mut dyn DataIter, shards: usize) -> Self {
        assert!(shards >= 1, "PartitionIter: shards must be >= 1");
        PartitionIter { inner, shards, weights: None, queue: VecDeque::new() }
    }

    /// Wrap `inner`, splitting each batch proportionally to `weights`
    /// ([`shard_ranges_weighted`]): the elastic-training geometry where a
    /// straggler replica receives a smaller slice of every global batch.
    /// Zero-weight shards are omitted from the stream.
    pub fn with_weights(inner: &'a mut dyn DataIter, weights: &[u32]) -> Self {
        assert!(!weights.is_empty(), "PartitionIter: weights must be non-empty");
        assert!(
            weights.iter().any(|&w| w > 0),
            "PartitionIter: at least one weight must be > 0"
        );
        PartitionIter {
            inner,
            shards: weights.len(),
            weights: Some(weights.to_vec()),
            queue: VecDeque::new(),
        }
    }

    /// The configured shard count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The next global batch, split into shards (at most `shards`
    /// entries; fewer when the batch has fewer rows than shards or some
    /// weights are zero).  `None` at epoch end.
    pub fn next_shards(&mut self) -> Option<Vec<DataBatch>> {
        let b = self.inner.next_batch()?;
        Some(match &self.weights {
            Some(w) => split_batch_weighted(&b, w),
            None => split_batch(&b, self.shards),
        })
    }
}

impl DataIter for PartitionIter<'_> {
    fn next_batch(&mut self) -> Option<DataBatch> {
        if self.queue.is_empty() {
            let group = self.next_shards()?;
            self.queue.extend(group);
        }
        self.queue.pop_front()
    }

    fn reset(&mut self) {
        self.queue.clear();
        self.inner.reset();
    }

    fn batch_size(&self) -> usize {
        match &self.weights {
            // largest shard size (the first shards get the remainder rows)
            None => self.inner.batch_size().div_ceil(self.shards),
            Some(w) => {
                let total: u64 = w.iter().map(|&x| x as u64).sum();
                let wmax = *w.iter().max().unwrap() as u64;
                (self.inner.batch_size() as u64 * wmax).div_ceil(total) as usize
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::default_engine;
    use crate::io::ArrayDataIter;

    fn iter(n: usize, batch: usize) -> ArrayDataIter {
        let feats: Vec<f32> = (0..n * 2).map(|v| v as f32).collect();
        let labels: Vec<f32> = (0..n).map(|v| v as f32).collect();
        ArrayDataIter::new(feats, labels, &[2], batch, false, default_engine())
    }

    #[test]
    fn even_split_preserves_rows_in_order() {
        let mut it = iter(8, 8);
        let mut p = PartitionIter::new(&mut it, 4);
        let shards = p.next_shards().unwrap();
        assert_eq!(shards.len(), 4);
        let mut labels = Vec::new();
        for s in &shards {
            assert_eq!(s.data.shape(), &[2, 2]);
            assert_eq!(s.label.size(), 2);
            labels.extend(s.label.to_vec());
        }
        assert_eq!(labels, (0..8).map(|v| v as f32).collect::<Vec<_>>());
    }

    #[test]
    fn uneven_split_sizes_differ_by_at_most_one() {
        // 10 rows over 4 shards -> [3, 3, 2, 2]
        let mut it = iter(10, 10);
        let mut p = PartitionIter::new(&mut it, 4);
        let shards = p.next_shards().unwrap();
        let sizes: Vec<usize> = shards.iter().map(|s| s.label.size()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        // contiguous coverage, no row lost or duplicated
        let all: Vec<f32> = shards.iter().flat_map(|s| s.label.to_vec()).collect();
        assert_eq!(all, (0..10).map(|v| v as f32).collect::<Vec<_>>());
        // features travel with their rows
        assert_eq!(shards[1].data.to_vec()[0], 6.0, "row 3 starts at feature 6");
    }

    #[test]
    fn shard_ranges_geometry() {
        assert_eq!(shard_ranges(8, 4), vec![(0, 2), (2, 2), (4, 2), (6, 2)]);
        assert_eq!(shard_ranges(10, 4), vec![(0, 3), (3, 3), (6, 2), (8, 2)]);
        assert_eq!(shard_ranges(2, 4), vec![(0, 1), (1, 1)]);
        assert_eq!(shard_ranges(5, 1), vec![(0, 5)]);
        // covers exactly, in order
        for (rows, shards) in [(17usize, 5usize), (64, 8), (3, 7)] {
            let rs = shard_ranges(rows, shards);
            let mut expect = 0;
            for (off, n) in rs {
                assert_eq!(off, expect);
                assert!(n >= 1);
                expect += n;
            }
            assert_eq!(expect, rows);
        }
    }

    #[test]
    fn weighted_ranges_split_proportionally() {
        // weights {3, 1}: 8 rows -> 6:2, 4 rows -> 3:1
        assert_eq!(shard_ranges_weighted(8, &[3, 1]), vec![(0, 6), (6, 2)]);
        assert_eq!(shard_ranges_weighted(4, &[3, 1]), vec![(0, 3), (3, 1)]);
        // largest-remainder ties resolve to the lower index
        assert_eq!(shard_ranges_weighted(4, &[1, 1, 1]), vec![(0, 2), (2, 1), (3, 1)]);
        // a degenerate zero-weight replica is omitted entirely
        assert_eq!(shard_ranges_weighted(4, &[2, 0, 2]), vec![(0, 2), (2, 2)]);
        // equal weights reproduce the unweighted geometry exactly
        for (rows, shards) in [(10usize, 4usize), (17, 5), (8, 4), (3, 7)] {
            let equal = vec![1u32; shards];
            assert_eq!(
                shard_ranges_weighted(rows, &equal),
                shard_ranges(rows, shards),
                "rows {rows} shards {shards}"
            );
        }
        // covers exactly, in order, for skewed weights
        for (rows, weights) in [(17usize, vec![5u32, 1, 3]), (64, vec![7, 2]), (9, vec![1, 8])] {
            let rs = shard_ranges_weighted(rows, &weights);
            let mut expect = 0;
            for (off, n) in rs {
                assert_eq!(off, expect);
                assert!(n >= 1);
                expect += n;
            }
            assert_eq!(expect, rows);
        }
    }

    #[test]
    fn weighted_partition_iter_streams_proportional_shards() {
        let mut it = iter(8, 8);
        let mut p = PartitionIter::with_weights(&mut it, &[3, 1]);
        assert_eq!(p.shards(), 2);
        assert_eq!(p.batch_size(), 6, "largest weighted shard");
        let shards = p.next_shards().unwrap();
        let sizes: Vec<usize> = shards.iter().map(|s| s.label.size()).collect();
        assert_eq!(sizes, vec![6, 2]);
        // contiguous coverage, rows travel with their features
        let all: Vec<f32> = shards.iter().flat_map(|s| s.label.to_vec()).collect();
        assert_eq!(all, (0..8).map(|v| v as f32).collect::<Vec<_>>());
        assert_eq!(shards[1].data.to_vec()[0], 12.0, "row 6 starts at feature 12");
    }

    #[test]
    fn tiny_batch_omits_empty_shards() {
        let mut it = iter(2, 2);
        let mut p = PartitionIter::new(&mut it, 4);
        let shards = p.next_shards().unwrap();
        assert_eq!(shards.len(), 2);
        assert!(shards.iter().all(|s| s.label.size() == 1));
    }

    #[test]
    fn deterministic_across_instances() {
        for _ in 0..2 {
            let mut a = iter(12, 6);
            let mut b = iter(12, 6);
            let mut pa = PartitionIter::new(&mut a, 3);
            let mut pb = PartitionIter::new(&mut b, 3);
            while let (Some(ga), Some(gb)) = (pa.next_shards(), pb.next_shards()) {
                for (x, y) in ga.iter().zip(&gb) {
                    assert_eq!(x.data.to_vec(), y.data.to_vec());
                    assert_eq!(x.label.to_vec(), y.label.to_vec());
                }
            }
        }
    }

    #[test]
    fn data_iter_impl_flattens_shards_in_order() {
        let mut plain = iter(8, 4);
        let mut sharded = iter(8, 4);
        let mut p = PartitionIter::new(&mut sharded, 2);
        assert_eq!(p.batch_size(), 2);
        let mut flat = Vec::new();
        while let Some(b) = p.next_batch() {
            assert_eq!(b.label.size(), 2);
            flat.extend(b.label.to_vec());
        }
        let mut expect = Vec::new();
        while let Some(b) = plain.next_batch() {
            expect.extend(b.label.to_vec());
        }
        assert_eq!(flat, expect, "shards concatenate back to the inner stream");
        // reset restarts cleanly
        p.reset();
        assert_eq!(p.next_batch().unwrap().label.to_vec(), vec![0.0, 1.0]);
    }
}

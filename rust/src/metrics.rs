//! Lightweight process-wide counters and timers for profiling the
//! coordinator (used by the perf pass and exposed by the CLI).

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

fn registry() -> &'static Mutex<HashMap<&'static str, u64>> {
    static REGISTRY: OnceLock<Mutex<HashMap<&'static str, u64>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

fn timers() -> &'static Mutex<HashMap<&'static str, Duration>> {
    static TIMERS: OnceLock<Mutex<HashMap<&'static str, Duration>>> = OnceLock::new();
    TIMERS.get_or_init(|| Mutex::new(HashMap::new()))
}

fn histograms() -> &'static Mutex<HashMap<&'static str, Histogram>> {
    static HISTS: OnceLock<Mutex<HashMap<&'static str, Histogram>>> = OnceLock::new();
    HISTS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Default reservoir capacity for named histograms.
pub const HISTOGRAM_CAP: usize = 4096;

/// A bounded-reservoir histogram for latency-style measurements.
///
/// Keeps at most `cap` samples via reservoir sampling (Vitter's
/// algorithm R) over a deterministic xorshift stream: memory stays
/// bounded no matter how many observations arrive, while the retained
/// sample remains uniformly representative of the whole stream — good
/// enough for the p50/p95/p99 the serving layer reports.
#[derive(Debug, Clone)]
pub struct Histogram {
    cap: usize,
    samples: Vec<u64>,
    count: u64,
    state: u64,
}

impl Histogram {
    /// Create a histogram retaining at most `cap` samples (clamped >= 1).
    pub fn new(cap: usize) -> Self {
        Histogram {
            cap: cap.max(1),
            samples: Vec::new(),
            count: 0,
            state: 0x9E37_79B9_7F4A_7C15,
        }
    }

    /// Record one observation.
    pub fn observe(&mut self, value: u64) {
        self.count += 1;
        if self.samples.len() < self.cap {
            self.samples.push(value);
            return;
        }
        // xorshift64* draw, then algorithm R: replace a random slot with
        // probability cap/count.
        self.state ^= self.state >> 12;
        self.state ^= self.state << 25;
        self.state ^= self.state >> 27;
        let j = self.state.wrapping_mul(0x2545_F491_4F6C_DD1D) % self.count;
        if (j as usize) < self.cap {
            self.samples[j as usize] = value;
        }
    }

    /// Total observations seen (not just retained).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Whether nothing has been observed.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Nearest-rank percentile (`p` in (0, 100]) over the retained
    /// reservoir; 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        self.percentiles(&[p])[0]
    }

    /// Several nearest-rank percentiles from one sorted snapshot — use
    /// this for p50/p95/p99 triples so callers holding a lock pay for a
    /// single clone+sort instead of one per percentile.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<u64> {
        if self.samples.is_empty() {
            return vec![0; ps.len()];
        }
        let mut v = self.samples.clone();
        v.sort_unstable();
        ps.iter()
            .map(|p| {
                let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
                v[rank.clamp(1, v.len()) - 1]
            })
            .collect()
    }
}

/// Record a microsecond-scale observation into a named global histogram
/// (created on first use with [`HISTOGRAM_CAP`]).
pub fn observe_us(name: &'static str, us: u64) {
    observe_us_all(name, &[us]);
}

/// Record a batch of observations under a single registry lock — the
/// form the serving reply loop uses (one lock per dispatched batch, not
/// one per request).
pub fn observe_us_all(name: &'static str, us: &[u64]) {
    let mut map = histograms().lock().unwrap();
    let h = map.entry(name).or_insert_with(|| Histogram::new(HISTOGRAM_CAP));
    for &v in us {
        h.observe(v);
    }
}

/// Percentile of a named global histogram (0 when absent).
pub fn percentile_us(name: &'static str, p: f64) -> u64 {
    histograms().lock().unwrap().get(name).map(|h| h.percentile(p)).unwrap_or(0)
}

/// Increment a named counter.
pub fn incr(name: &'static str, by: u64) {
    *registry().lock().unwrap().entry(name).or_insert(0) += by;
}

/// Read a counter.
pub fn get(name: &'static str) -> u64 {
    registry().lock().unwrap().get(name).copied().unwrap_or(0)
}

/// Time a closure, accumulating into a named timer.
pub fn time<T>(name: &'static str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    *timers().lock().unwrap().entry(name).or_insert(Duration::ZERO) += t0.elapsed();
    out
}

/// Accumulated time for a timer, in seconds.
pub fn timer_s(name: &'static str) -> f64 {
    timers().lock().unwrap().get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0)
}

/// All counters as `(name, value)`, sorted by name — the stable order
/// both [`report`] and `profile::MetricsSnapshot` serialize.
pub fn counters_sorted() -> Vec<(String, u64)> {
    let mut v: Vec<(String, u64)> =
        registry().lock().unwrap().iter().map(|(k, &n)| (k.to_string(), n)).collect();
    v.sort();
    v
}

/// All timers as `(name, seconds)`, sorted by name.
pub fn timers_sorted() -> Vec<(String, f64)> {
    let mut v: Vec<(String, f64)> =
        timers().lock().unwrap().iter().map(|(k, d)| (k.to_string(), d.as_secs_f64())).collect();
    v.sort_by(|a, b| a.0.cmp(&b.0));
    v
}

/// All histograms as `(name, count, [p50, p95, p99])`, sorted by name.
/// One lock + one reservoir sort per histogram.
pub fn histograms_sorted() -> Vec<(String, u64, [u64; 3])> {
    let mut v: Vec<(String, u64, [u64; 3])> = histograms()
        .lock()
        .unwrap()
        .iter()
        .map(|(k, h)| {
            let p = h.percentiles(&[50.0, 95.0, 99.0]);
            (k.to_string(), h.count(), [p[0], p[1], p[2]])
        })
        .collect();
    v.sort_by(|a, b| a.0.cmp(&b.0));
    v
}

/// Snapshot all counters, timers and histograms as a report with lines
/// in sorted order (reproducible given identical observations — the
/// reservoir stream is deterministically seeded).
pub fn report() -> String {
    let mut lines: Vec<String> =
        counters_sorted().into_iter().map(|(k, v)| format!("{k}: {v}")).collect();
    lines.extend(timers_sorted().into_iter().map(|(k, s)| format!("{k}: {s:.6}s")));
    lines.extend(histograms_sorted().into_iter().map(|(k, n, p)| {
        format!("{k}: n={n} p50={}us p95={}us p99={}us", p[0], p[1], p[2])
    }));
    lines.sort();
    lines.join("\n")
}

/// Reset everything (tests).
pub fn reset() {
    registry().lock().unwrap().clear();
    timers().lock().unwrap().clear();
    histograms().lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        reset();
        incr("test.counter", 2);
        incr("test.counter", 3);
        assert_eq!(get("test.counter"), 5);
    }

    #[test]
    fn timers_accumulate_and_report() {
        reset();
        let v = time("test.timer", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(timer_s("test.timer") >= 0.004);
        assert!(report().contains("test.timer"));
    }

    #[test]
    fn histogram_exact_percentiles_below_cap() {
        // Fewer observations than the cap: no sampling, percentiles are
        // exact nearest-rank values.
        let mut h = Histogram::new(HISTOGRAM_CAP);
        for v in 1..=1000u64 {
            h.observe(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.percentile(50.0), 500);
        assert_eq!(h.percentile(95.0), 950);
        assert_eq!(h.percentile(99.0), 990);
        assert_eq!(h.percentile(100.0), 1000);
    }

    #[test]
    fn histogram_reservoir_stays_bounded_and_representative() {
        let mut h = Histogram::new(256);
        for v in 0..100_000u64 {
            h.observe(v);
        }
        assert_eq!(h.count(), 100_000);
        assert!(h.samples.len() <= 256);
        // Uniform stream 0..100k: the sampled median should land well
        // inside the middle half.
        let p50 = h.percentile(50.0);
        assert!((25_000..75_000).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn named_histograms_appear_in_report() {
        reset();
        for v in [100u64, 200, 300] {
            observe_us("test.latency_us", v);
        }
        assert_eq!(percentile_us("test.latency_us", 50.0), 200);
        assert_eq!(percentile_us("test.absent", 50.0), 0);
        let rep = report();
        assert!(rep.contains("test.latency_us"), "{rep}");
        assert!(rep.contains("p95="), "{rep}");
    }
}

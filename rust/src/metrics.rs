//! Lightweight process-wide counters and timers for profiling the
//! coordinator (used by the perf pass and exposed by the CLI).

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

fn registry() -> &'static Mutex<HashMap<&'static str, u64>> {
    static REGISTRY: OnceLock<Mutex<HashMap<&'static str, u64>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

fn timers() -> &'static Mutex<HashMap<&'static str, Duration>> {
    static TIMERS: OnceLock<Mutex<HashMap<&'static str, Duration>>> = OnceLock::new();
    TIMERS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Increment a named counter.
pub fn incr(name: &'static str, by: u64) {
    *registry().lock().unwrap().entry(name).or_insert(0) += by;
}

/// Read a counter.
pub fn get(name: &'static str) -> u64 {
    registry().lock().unwrap().get(name).copied().unwrap_or(0)
}

/// Time a closure, accumulating into a named timer.
pub fn time<T>(name: &'static str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    *timers().lock().unwrap().entry(name).or_insert(Duration::ZERO) += t0.elapsed();
    out
}

/// Accumulated time for a timer, in seconds.
pub fn timer_s(name: &'static str) -> f64 {
    timers().lock().unwrap().get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0)
}

/// Snapshot all counters and timers as a sorted report.
pub fn report() -> String {
    let counters = registry().lock().unwrap();
    let timers = timers().lock().unwrap();
    let mut lines: Vec<String> = counters.iter().map(|(k, v)| format!("{k}: {v}")).collect();
    lines.extend(timers.iter().map(|(k, v)| format!("{k}: {:.6}s", v.as_secs_f64())));
    lines.sort();
    lines.join("\n")
}

/// Reset everything (tests).
pub fn reset() {
    registry().lock().unwrap().clear();
    timers().lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        reset();
        incr("test.counter", 2);
        incr("test.counter", 3);
        assert_eq!(get("test.counter"), 5);
    }

    #[test]
    fn timers_accumulate_and_report() {
        reset();
        let v = time("test.timer", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(timer_s("test.timer") >= 0.004);
        assert!(report().contains("test.timer"));
    }
}

//! # mixnet — a Rust + JAX + Pallas reproduction of MXNet (2015)
//!
//! `mixnet` rebuilds the system described in *"MXNet: A Flexible and
//! Efficient Machine Learning Library for Heterogeneous Distributed
//! Systems"* (Chen et al., 2015) as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the framework itself: a tag-based
//!   [dependency engine](engine) that schedules both imperative
//!   [`NDArray`](ndarray::NDArray) operations and declarative
//!   [`Symbol`](symbol::Symbol) graphs, a [computation graph](graph) with
//!   symbolic autodiff and the paper's *inplace* / *co-share* [memory
//!   planner](graph::memory), a [graph executor](executor), a two-level
//!   parameter-server [`KVStore`](kvstore), [RecordIO data I/O](io),
//!   [optimizers](optimizer), a [training module](module) and a
//!   [dynamic-batching inference server](serve).
//! * **Layer 2 (build-time Python)** — a JAX transformer / MLP forward +
//!   backward, AOT-lowered to HLO text in `artifacts/` by
//!   `python/compile/aot.py`.
//! * **Layer 1 (build-time Python)** — Pallas kernels for the fused
//!   linear+activation and softmax-cross-entropy "big ops", validated
//!   against a pure-jnp oracle.
//!
//! The [runtime] module loads the AOT artifacts through PJRT (the `xla`
//! crate) so that Python never runs on the training hot path.
//!
//! ## Quickstart
//!
//! ```
//! use mixnet::prelude::*;
//!
//! // Imperative NDArray computation, lazily scheduled on the engine:
//! let a = NDArray::ones(&[2, 3]);
//! let b = &a * 2.0;
//! assert_eq!(b.to_vec(), vec![2.0; 6]);
//!
//! // Declarative symbolic MLP (see `examples/quickstart.rs` for binding
//! // and training it):
//! let mlp = Symbol::var("data")
//!     .fully_connected("fc1", 64)
//!     .activation("relu1", Act::Relu)
//!     .fully_connected("fc2", 10)
//!     .softmax_output("softmax");
//! assert_eq!(mlp.name(), "softmax");
//! ```

pub mod engine;
pub mod error;
pub mod executor;
pub mod graph;
pub mod io;
pub mod kvstore;
pub mod metrics;
pub mod models;
pub mod module;
pub mod ndarray;
pub mod optimizer;
pub mod profile;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod symbol;
pub mod util;

pub use error::{Error, Result};

/// Commonly used items, re-exported for examples and downstream users.
pub mod prelude {
    pub use crate::engine::{Engine, EngineKind, EngineRef};
    pub use crate::error::{Error, Result};
    pub use crate::executor::Executor;
    pub use crate::graph::memory::AllocStrategy;
    pub use crate::graph::Graph;
    pub use crate::io::{DataBatch, DataIter, PartitionIter};
    pub use crate::kvstore::KVStore;
    pub use crate::module::{
        Context, DataParallelTrainer, Module, SyncMode, SyncPolicy, TrainerConfig,
    };
    pub use crate::ndarray::NDArray;
    pub use crate::optimizer::{Optimizer, Sgd};
    pub use crate::serve::{Servable, ServeConfig, Server};
    pub use crate::symbol::{Act, Symbol};
}

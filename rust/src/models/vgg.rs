//! VGG (configurations A/11 and D/16) — the paper singles VGG out in
//! Figure 7 ("even for the most expensive VGG net, training needs less
//! than 16MB extra").

use super::Model;
use crate::symbol::{Act, Pool, Symbol};

/// Which VGG configuration to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VggDepth {
    /// Configuration A (8 conv + 3 fc).
    Vgg11,
    /// Configuration D (13 conv + 3 fc).
    Vgg16,
}

/// Per-stage conv counts for each configuration (stages are separated by
/// 2x2 max-pools; filter widths double per stage: 64..512).
fn stages(depth: VggDepth) -> [usize; 5] {
    match depth {
        VggDepth::Vgg11 => [1, 1, 2, 2, 2],
        VggDepth::Vgg16 => [2, 2, 3, 3, 3],
    }
}

/// VGG on `hw`x`hw` RGB input.  `hw` must be divisible by 32 (five 2x
/// pools); 224 reproduces the paper's setting.
pub fn vgg(depth: VggDepth, num_classes: usize, hw: usize) -> Model {
    assert!(hw >= 32 && hw % 32 == 0, "vgg needs input divisible by 32, got {hw}");
    let widths = [64usize, 128, 256, 512, 512];
    let mut x = Symbol::var("data");
    for (stage, (&n_convs, &width)) in stages(depth).iter().zip(&widths).enumerate() {
        for c in 0..n_convs {
            let name = format!("conv{}_{}", stage + 1, c + 1);
            x = x
                .convolution(&name, width, 3, 1, 1)
                .activation(&format!("relu{}_{}", stage + 1, c + 1), Act::Relu);
        }
        x = x.pooling(&format!("pool{}", stage + 1), Pool::Max, 2, 2, 0);
    }
    let out = x
        .flatten("flat")
        .fully_connected("fc6", 4096)
        .activation("relu6", Act::Relu)
        .dropout("drop6", 0.5)
        .fully_connected("fc7", 4096)
        .activation("relu7", Act::Relu)
        .dropout("drop7", 0.5)
        .fully_connected("fc8", num_classes)
        .softmax_output("softmax");
    let name = match depth {
        VggDepth::Vgg11 => "vgg-11",
        VggDepth::Vgg16 => "vgg-16",
    };
    Model {
        name: format!("{name}@{hw}"),
        symbol: out,
        feat_shape: vec![3, hw, hw],
        num_classes,
    }
}

/// The VGG-11 *convolutional tower* with a small classifier head: the
/// full five conv stages (where the activation memory lives) but a
/// 256-wide fc in place of the 4096-wide pair, so activations — not
/// parameters/gradients — dominate the footprint.  One of the two
/// sublinear-memory benchmark workloads (CI bounds its measured
/// peak-pool ratio).  Note the pyramid geometry puts a floor under that
/// ratio: stage 1's activation and its gradient (2 x the largest tensor)
/// must coexist during segment-1 backward whatever the checkpoint
/// placement, and the whole memopt-off footprint is only ~2.8 x that
/// tensor — the full 0.6 x sublinear win needs the uniform-depth
/// [`conv_tower`] shape instead.
pub fn vgg11_tower(num_classes: usize, hw: usize) -> Model {
    assert!(hw >= 32 && hw % 32 == 0, "vgg needs input divisible by 32, got {hw}");
    let widths = [64usize, 128, 256, 512, 512];
    let mut x = Symbol::var("data");
    for (stage, (&n_convs, &width)) in stages(VggDepth::Vgg11).iter().zip(&widths).enumerate() {
        for c in 0..n_convs {
            let name = format!("conv{}_{}", stage + 1, c + 1);
            x = x
                .convolution(&name, width, 3, 1, 1)
                .activation(&format!("relu{}_{}", stage + 1, c + 1), Act::Relu);
        }
        x = x.pooling(&format!("pool{}", stage + 1), Pool::Max, 2, 2, 0);
    }
    let out = x
        .flatten("flat")
        .fully_connected("fc6", 256)
        .activation("relu6", Act::Relu)
        .dropout("drop6", 0.5)
        .fully_connected("fc7", num_classes)
        .softmax_output("softmax");
    Model {
        name: format!("vgg11-tower@{hw}"),
        symbol: out,
        feat_shape: vec![3, hw, hw],
        num_classes,
    }
}

/// A plain `depth`-layer convolutional tower at constant spatial
/// resolution — conv(3x3, `width`) + relu stacked `depth` times, one 2x2
/// max-pool, and a small softmax head.  Uniform per-layer activations
/// are exactly the n-layer setting of the sublinear-memory analysis
/// (§3.1 mirror nodes): memopt-off must hold all n activations across
/// the forward/backward boundary while the recompute rewrite holds
/// K checkpoints plus one segment, so the measured peak-pool ratio
/// approaches (2√n)/n with no pyramid floor.  This is the workload CI
/// gates at `recompute_mem_ratio <= 0.6`.
pub fn conv_tower(depth: usize, width: usize, num_classes: usize, hw: usize) -> Model {
    assert!(depth >= 2, "conv_tower needs depth >= 2, got {depth}");
    assert!(hw >= 4 && hw % 2 == 0, "conv_tower needs even input >= 4, got {hw}");
    let mut x = Symbol::var("data");
    for i in 0..depth {
        x = x
            .convolution(&format!("conv{}", i + 1), width, 3, 1, 1)
            .activation(&format!("relu{}", i + 1), Act::Relu);
    }
    let out = x
        .pooling("pool", Pool::Max, 2, 2, 0)
        .flatten("flat")
        .fully_connected("fc", num_classes)
        .softmax_output("softmax");
    Model {
        name: format!("conv-tower@{hw}x{depth}"),
        symbol: out,
        feat_shape: vec![3, hw, hw],
        num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg11_classic_shapes() {
        let m = vgg(VggDepth::Vgg11, 1000, 224);
        let ps = m.param_shapes(64).unwrap();
        assert_eq!(ps["conv1_1_weight"], vec![64, 3, 3, 3]);
        assert_eq!(ps["conv5_2_weight"], vec![512, 512, 3, 3]);
        // 224 / 2^5 = 7
        assert_eq!(ps["fc6_weight"], vec![4096, 512 * 7 * 7]);
    }

    #[test]
    fn vgg16_has_13_convs() {
        let m = vgg(VggDepth::Vgg16, 1000, 224);
        let ps = m.param_shapes(2).unwrap();
        let convs = ps.keys().filter(|k| k.starts_with("conv") && k.ends_with("_weight")).count();
        assert_eq!(convs, 13);
    }

    #[test]
    #[should_panic(expected = "divisible by 32")]
    fn vgg_rejects_odd_input() {
        vgg(VggDepth::Vgg11, 10, 100);
    }

    #[test]
    fn conv_tower_is_uniform_depth() {
        let m = conv_tower(12, 64, 10, 32);
        assert_eq!(m.name, "conv-tower@32x12");
        let ps = m.param_shapes(8).unwrap();
        let convs = ps.keys().filter(|k| k.starts_with("conv") && k.ends_with("_weight")).count();
        assert_eq!(convs, 12);
        assert_eq!(ps["conv1_weight"], vec![64, 3, 3, 3]);
        assert_eq!(ps["conv12_weight"], vec![64, 64, 3, 3]);
        // constant resolution until the single head pool: 32 / 2 = 16
        assert_eq!(ps["fc_weight"], vec![10, 64 * 16 * 16]);
    }
}

//! VGG (configurations A/11 and D/16) — the paper singles VGG out in
//! Figure 7 ("even for the most expensive VGG net, training needs less
//! than 16MB extra").

use super::Model;
use crate::symbol::{Act, Pool, Symbol};

/// Which VGG configuration to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VggDepth {
    /// Configuration A (8 conv + 3 fc).
    Vgg11,
    /// Configuration D (13 conv + 3 fc).
    Vgg16,
}

/// Per-stage conv counts for each configuration (stages are separated by
/// 2x2 max-pools; filter widths double per stage: 64..512).
fn stages(depth: VggDepth) -> [usize; 5] {
    match depth {
        VggDepth::Vgg11 => [1, 1, 2, 2, 2],
        VggDepth::Vgg16 => [2, 2, 3, 3, 3],
    }
}

/// VGG on `hw`x`hw` RGB input.  `hw` must be divisible by 32 (five 2x
/// pools); 224 reproduces the paper's setting.
pub fn vgg(depth: VggDepth, num_classes: usize, hw: usize) -> Model {
    assert!(hw >= 32 && hw % 32 == 0, "vgg needs input divisible by 32, got {hw}");
    let widths = [64usize, 128, 256, 512, 512];
    let mut x = Symbol::var("data");
    for (stage, (&n_convs, &width)) in stages(depth).iter().zip(&widths).enumerate() {
        for c in 0..n_convs {
            let name = format!("conv{}_{}", stage + 1, c + 1);
            x = x
                .convolution(&name, width, 3, 1, 1)
                .activation(&format!("relu{}_{}", stage + 1, c + 1), Act::Relu);
        }
        x = x.pooling(&format!("pool{}", stage + 1), Pool::Max, 2, 2, 0);
    }
    let out = x
        .flatten("flat")
        .fully_connected("fc6", 4096)
        .activation("relu6", Act::Relu)
        .dropout("drop6", 0.5)
        .fully_connected("fc7", 4096)
        .activation("relu7", Act::Relu)
        .dropout("drop7", 0.5)
        .fully_connected("fc8", num_classes)
        .softmax_output("softmax");
    let name = match depth {
        VggDepth::Vgg11 => "vgg-11",
        VggDepth::Vgg16 => "vgg-16",
    };
    Model {
        name: format!("{name}@{hw}"),
        symbol: out,
        feat_shape: vec![3, hw, hw],
        num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg11_classic_shapes() {
        let m = vgg(VggDepth::Vgg11, 1000, 224);
        let ps = m.param_shapes(64).unwrap();
        assert_eq!(ps["conv1_1_weight"], vec![64, 3, 3, 3]);
        assert_eq!(ps["conv5_2_weight"], vec![512, 512, 3, 3]);
        // 224 / 2^5 = 7
        assert_eq!(ps["fc6_weight"], vec![4096, 512 * 7 * 7]);
    }

    #[test]
    fn vgg16_has_13_convs() {
        let m = vgg(VggDepth::Vgg16, 1000, 224);
        let ps = m.param_shapes(2).unwrap();
        let convs = ps.keys().filter(|k| k.starts_with("conv") && k.ends_with("_weight")).count();
        assert_eq!(convs, 13);
    }

    #[test]
    #[should_panic(expected = "divisible by 32")]
    fn vgg_rejects_odd_input() {
        vgg(VggDepth::Vgg11, 10, 100);
    }
}

//! Inception-BN — "googlenet with batch normalization", the network of
//! the paper's Figure 8 scalability experiment (and a Figure 6/7
//! workload).  Follows the classic MXNet `inception-bn` example: factory
//! blocks of (1x1), (1x1 -> 3x3), (1x1 -> double 3x3) and (pool -> 1x1
//! proj) branches concatenated along channels, with BN after every conv.

use super::Model;
use crate::symbol::{Act, Pool, Symbol};

/// conv -> BN -> ReLU (the "ConvFactory" of the MXNet example).
fn conv_bn(
    x: &Symbol,
    name: &str,
    num_filter: usize,
    kernel: usize,
    stride: usize,
    pad: usize,
) -> Symbol {
    x.convolution(&format!("{name}_conv"), num_filter, kernel, stride, pad)
        .batch_norm(&format!("{name}_bn"))
        .activation(&format!("{name}_relu"), Act::Relu)
}

/// Inception factory A: 1x1 | 1x1->3x3 | 1x1->3x3->3x3 | pool->1x1proj.
#[allow(clippy::too_many_arguments)]
fn inception_a(
    x: &Symbol,
    name: &str,
    f1: usize,
    f3r: usize,
    f3: usize,
    fd3r: usize,
    fd3: usize,
    proj: usize,
    pool: Pool,
) -> Symbol {
    let b1 = conv_bn(x, &format!("{name}_1x1"), f1, 1, 1, 0);
    let b3 = conv_bn(x, &format!("{name}_3x3r"), f3r, 1, 1, 0);
    let b3 = conv_bn(&b3, &format!("{name}_3x3"), f3, 3, 1, 1);
    let bd = conv_bn(x, &format!("{name}_d3x3r"), fd3r, 1, 1, 0);
    let bd = conv_bn(&bd, &format!("{name}_d3x3a"), fd3, 3, 1, 1);
    let bd = conv_bn(&bd, &format!("{name}_d3x3b"), fd3, 3, 1, 1);
    let bp = x.pooling(&format!("{name}_pool"), pool, 3, 1, 1);
    let bp = conv_bn(&bp, &format!("{name}_proj"), proj, 1, 1, 0);
    Symbol::concat(&format!("{name}_concat"), &[b1, b3, bd, bp])
}

/// Inception factory B (downsample): 1x1->3x3/2 | 1x1->3x3->3x3/2 | pool/2.
fn inception_b(x: &Symbol, name: &str, f3r: usize, f3: usize, fd3r: usize, fd3: usize) -> Symbol {
    let b3 = conv_bn(x, &format!("{name}_3x3r"), f3r, 1, 1, 0);
    let b3 = conv_bn(&b3, &format!("{name}_3x3"), f3, 3, 2, 1);
    let bd = conv_bn(x, &format!("{name}_d3x3r"), fd3r, 1, 1, 0);
    let bd = conv_bn(&bd, &format!("{name}_d3x3a"), fd3, 3, 1, 1);
    let bd = conv_bn(&bd, &format!("{name}_d3x3b"), fd3, 3, 2, 1);
    let bp = x.pooling(&format!("{name}_pool"), Pool::Max, 3, 2, 1);
    Symbol::concat(&format!("{name}_concat"), &[b3, bd, bp])
}

/// Inception-BN on `hw`x`hw` RGB input (224 reproduces the paper; the
/// global average pool adapts to the final spatial extent).  `hw` must be
/// divisible by 32.
pub fn inception_bn(num_classes: usize, hw: usize) -> Model {
    assert!(hw >= 32 && hw % 32 == 0, "inception-bn needs input divisible by 32, got {hw}");
    let data = Symbol::var("data");
    // stem: 7x7/2 -> pool/2 -> 1x1 -> 3x3 -> pool/2
    let x = conv_bn(&data, "stem1", 64, 7, 2, 3);
    let x = x.pooling("stem_pool1", Pool::Max, 3, 2, 1);
    let x = conv_bn(&x, "stem2r", 64, 1, 1, 0);
    let x = conv_bn(&x, "stem2", 192, 3, 1, 1);
    let x = x.pooling("stem_pool2", Pool::Max, 3, 2, 1);
    // 3a, 3b, 3c
    let x = inception_a(&x, "in3a", 64, 64, 64, 64, 96, 32, Pool::Avg);
    let x = inception_a(&x, "in3b", 64, 64, 96, 64, 96, 64, Pool::Avg);
    let x = inception_b(&x, "in3c", 128, 160, 64, 96);
    // 4a..4e
    let x = inception_a(&x, "in4a", 224, 64, 96, 96, 128, 128, Pool::Avg);
    let x = inception_a(&x, "in4b", 192, 96, 128, 96, 128, 128, Pool::Avg);
    let x = inception_a(&x, "in4c", 160, 128, 160, 128, 160, 128, Pool::Avg);
    let x = inception_a(&x, "in4d", 96, 128, 192, 160, 192, 128, Pool::Avg);
    let x = inception_b(&x, "in4e", 128, 192, 192, 256);
    // 5a, 5b
    let x = inception_a(&x, "in5a", 352, 192, 320, 160, 224, 128, Pool::Avg);
    let x = inception_a(&x, "in5b", 352, 192, 320, 192, 224, 128, Pool::Max);
    // global average pool over the remaining extent (7 at hw=224)
    let final_hw = hw / 32;
    let x = x.pooling("global_pool", Pool::Avg, final_hw, 1, 0);
    let out = x
        .flatten("flat")
        .fully_connected("fc1", num_classes)
        .softmax_output("softmax");
    Model {
        name: format!("inception-bn@{hw}"),
        symbol: out,
        feat_shape: vec![3, hw, hw],
        num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inception_bn_224_shapes() {
        let m = inception_bn(1000, 224);
        let ps = m.param_shapes(4).unwrap();
        assert_eq!(ps["stem1_conv_weight"], vec![64, 3, 7, 7]);
        // in5b concat = 352 + 320 + 224 + 128 = 1024 channels
        assert_eq!(ps["fc1_weight"], vec![1000, 1024]);
        // BN params exist for every conv
        assert!(ps.contains_key("in4c_3x3_bn_gamma"));
    }

    #[test]
    fn inception_channel_arithmetic() {
        // 3a: 64 + 64 + 96 + 32 = 256; 3b consumes 256.
        let m = inception_bn(10, 32);
        let ps = m.param_shapes(2).unwrap();
        assert_eq!(ps["in3b_1x1_conv_weight"][1], 256);
        // 3b: 64 + 96 + 96 + 64 = 320; 3c branches consume 320.
        assert_eq!(ps["in3c_3x3r_conv_weight"][1], 320);
    }
}

//! The paper's Figure 2 multi-layer perceptron and a small CNN used by
//! the convergence experiments (E3's scaled GoogLeNet stand-in).

use super::Model;
use crate::symbol::{Act, Pool, Symbol};

/// Multi-layer perceptron: `data -> [FC -> ReLU]* -> FC -> Softmax`
/// (the paper's Figure 2, generalized to arbitrary hidden widths).
pub fn mlp(hidden: &[usize], in_dim: usize, num_classes: usize) -> Model {
    let mut x = Symbol::var("data");
    for (i, &h) in hidden.iter().enumerate() {
        x = x
            .fully_connected(&format!("fc{}", i + 1), h)
            .activation(&format!("relu{}", i + 1), Act::Relu);
    }
    let out = x
        .fully_connected(&format!("fc{}", hidden.len() + 1), num_classes)
        .softmax_output("softmax");
    Model {
        name: "mlp".into(),
        symbol: out,
        feat_shape: vec![in_dim],
        num_classes,
    }
}

/// Small LeNet-style CNN on `hw`x`hw` single-channel input: the
/// convergence-experiment workhorse (full GoogLeNet fwd+bwd does not fit
/// a single-core budget; DESIGN §4 documents the substitution).
pub fn simple_cnn(num_classes: usize, hw: usize) -> Model {
    let out = Symbol::var("data")
        .convolution("conv1", 8, 3, 1, 1)
        .batch_norm("bn1")
        .activation("relu1", Act::Relu)
        .pooling("pool1", Pool::Max, 2, 2, 0)
        .convolution("conv2", 16, 3, 1, 1)
        .activation("relu2", Act::Relu)
        .pooling("pool2", Pool::Max, 2, 2, 0)
        .flatten("flat")
        .fully_connected("fc1", 64)
        .activation("relu3", Act::Relu)
        .fully_connected("fc2", num_classes)
        .softmax_output("softmax");
    Model {
        name: "simple-cnn".into(),
        symbol: out,
        feat_shape: vec![1, hw, hw],
        num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_param_shapes_solved() {
        let m = mlp(&[128, 64], 784, 10);
        let ps = m.param_shapes(32).unwrap();
        assert_eq!(ps["fc1_weight"], vec![128, 784]);
        assert_eq!(ps["fc2_weight"], vec![64, 128]);
        assert_eq!(ps["fc3_weight"], vec![10, 64]);
        assert_eq!(ps["fc3_bias"], vec![10]);
        assert!(!ps.contains_key("softmax_label"));
    }

    #[test]
    fn simple_cnn_shapes() {
        let m = simple_cnn(10, 28);
        let ps = m.param_shapes(8).unwrap();
        assert_eq!(ps["conv1_weight"], vec![8, 1, 3, 3]);
        assert_eq!(ps["bn1_gamma"], vec![8]);
        // 28 -> pool 14 -> pool 7; 16 channels
        assert_eq!(ps["fc1_weight"], vec![64, 16 * 7 * 7]);
    }
}

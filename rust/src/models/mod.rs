//! The model zoo (DESIGN S14): graph-structure definitions of the
//! networks the paper's evaluation uses — the Figure 2 MLP, AlexNet,
//! VGG and Inception-BN (the "googlenet with batch normalization" of
//! Figure 8) — plus a small CNN used by the convergence experiments.
//!
//! Models are plain [`Symbol`] builders; [`Model::param_shapes`] infers
//! every parameter's shape from the data shape the same way MXNet's
//! `infer_shape` does, so callers never hand-write weight dimensions.

pub mod alexnet;
pub mod inception;
pub mod mlp;
pub mod vgg;

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::graph::{Graph, Op};
use crate::ndarray::kernels::conv_out;
use crate::symbol::Symbol;

pub use alexnet::alexnet;
pub use inception::inception_bn;
pub use mlp::{mlp, simple_cnn};
pub use vgg::{conv_tower, vgg, vgg11_tower, VggDepth};

/// A network architecture: its symbol plus the per-example input shape it
/// expects (`feat_shape`, without the batch axis).
pub struct Model {
    /// Human-readable name ("alexnet", "vgg-11", ...).
    pub name: String,
    /// The declarative network with a `SoftmaxOutput` head.
    pub symbol: Symbol,
    /// Per-example feature shape, e.g. `[3, 224, 224]`.
    pub feat_shape: Vec<usize>,
    /// Output classes.
    pub num_classes: usize,
}

impl Model {
    /// Infer the shape of every parameter variable for batch size
    /// `batch` (MXNet's `infer_shape`).  Excludes `data` and `*_label`.
    pub fn param_shapes(&self, batch: usize) -> Result<HashMap<String, Vec<usize>>> {
        let graph = Symbol::to_graph(std::slice::from_ref(&self.symbol));
        let mut data_shape = vec![batch];
        data_shape.extend_from_slice(&self.feat_shape);
        let all = infer_param_shapes(&graph, &data_shape)?;
        Ok(all
            .into_iter()
            .filter(|(k, _)| k != "data" && !k.ends_with("_label"))
            .collect())
    }

    /// All variable shapes (including `data` and the label) for `batch`.
    pub fn var_shapes(&self, batch: usize) -> Result<HashMap<String, Vec<usize>>> {
        let graph = Symbol::to_graph(std::slice::from_ref(&self.symbol));
        let mut data_shape = vec![batch];
        data_shape.extend_from_slice(&self.feat_shape);
        infer_param_shapes(&graph, &data_shape)
    }

    /// The forward graph plus a complete variable-shape map for `batch`
    /// (what the memory-planner benches consume).
    pub fn graph(&self, batch: usize) -> Result<(Graph, HashMap<String, Vec<usize>>)> {
        let graph = Symbol::to_graph(std::slice::from_ref(&self.symbol));
        let shapes = self.var_shapes(batch)?;
        Ok((graph, shapes))
    }

    /// Total parameter count for `batch`-independent variables.
    pub fn num_params(&self) -> Result<usize> {
        Ok(self
            .param_shapes(1)?
            .values()
            .map(|s| s.iter().product::<usize>())
            .sum())
    }
}

/// Look up a model by name (used by the CLI and benches).
///
/// Known names: `mlp`, `alexnet`, `vgg-11`, `vgg11-tower`, `vgg-16`,
/// `conv-tower`, `inception-bn`, `simple-cnn`.  An optional `@HxW`
/// suffix scales the spatial input (e.g. `alexnet@64` builds AlexNet
/// topology on 64x64 input) — the substitution knob the benches use to
/// fit CPU budgets.
pub fn by_name(spec: &str) -> Result<Model> {
    let (name, hw) = match spec.split_once('@') {
        Some((n, s)) => {
            let hw: usize = s
                .parse()
                .map_err(|_| Error::Bind(format!("bad model spec '{spec}'")))?;
            (n, Some(hw))
        }
        None => (spec, None),
    };
    match name {
        "mlp" => Ok(mlp(&[128, 64], 784, 10)),
        "alexnet" => Ok(alexnet(1000, hw.unwrap_or(224))),
        "vgg-11" => Ok(vgg(VggDepth::Vgg11, 1000, hw.unwrap_or(224))),
        "vgg11-tower" => Ok(vgg11_tower(10, hw.unwrap_or(64))),
        "conv-tower" => Ok(conv_tower(16, 64, 10, hw.unwrap_or(32))),
        "vgg-16" => Ok(vgg(VggDepth::Vgg16, 1000, hw.unwrap_or(224))),
        "inception-bn" => Ok(inception_bn(1000, hw.unwrap_or(224))),
        "simple-cnn" => Ok(simple_cnn(10, hw.unwrap_or(28))),
        other => Err(Error::Bind(format!("unknown model '{other}'"))),
    }
}

/// Serving-scale MLP (784 -> 128 -> 64 -> `classes`): the workload the
/// serve bench and `mixnet serve` default to.  Row-pure (no BatchNorm),
/// so batched serving is bitwise lossless.
pub fn servable_mlp(in_dim: usize, num_classes: usize) -> Model {
    mlp(&[128, 64], in_dim, num_classes)
}

/// Serving-scale AlexNet: full topology on a reduced spatial input so a
/// CPU can hold several batch buckets (dropout is identity at inference;
/// no BatchNorm, so it is row-pure and lossless to batch).
pub fn servable_alexnet(num_classes: usize) -> Model {
    alexnet(num_classes, 64)
}

/// Infer all variable shapes of a *forward* graph given only the data
/// shape.  Parameter variables (weights, biases, gammas, labels, ...) are
/// solved from the layer attributes as the walk reaches their consumer —
/// the forward half of MXNet's bidirectional `infer_shape`.
pub fn infer_param_shapes(
    graph: &Graph,
    data_shape: &[usize],
) -> Result<HashMap<String, Vec<usize>>> {
    // shapes[node] = per-output dims, filled in topological order.
    let mut shapes: Vec<Vec<Vec<usize>>> = vec![vec![]; graph.nodes.len()];
    let mut vars: HashMap<String, Vec<usize>> = HashMap::new();
    vars.insert("data".to_string(), data_shape.to_vec());

    // Variables get their shape assigned by their consumer; remember node
    // id -> name so the consumer can write through.
    let err = |id: usize, msg: String| {
        Error::shape(format!("infer_param_shapes node {id} ({}): {msg}", graph.nodes[id].name))
    };

    fn get_shape(
        graph: &Graph,
        shapes: &[Vec<Vec<usize>>],
        e: &crate::graph::Entry,
    ) -> Result<Vec<usize>> {
        let s = &shapes[e.node][..];
        if e.out >= s.len() || s[e.out].is_empty() {
            return Err(Error::shape(format!(
                "shape of '{}' output {} needed before it is known",
                graph.nodes[e.node].name, e.out
            )));
        }
        Ok(s[e.out].clone())
    }

    for (id, node) in graph.nodes.iter().enumerate() {
        macro_rules! get {
            ($e:expr) => {
                get_shape(graph, &shapes, $e)
            };
        }
        // Assign a variable-input's shape (must match if already set).
        macro_rules! set_var {
            ($entry:expr, $shape:expr) => {{
                let e = $entry;
                let shape: Vec<usize> = $shape;
                let vnode = &graph.nodes[e.node];
                if !vnode.op.is_variable() {
                    let got = get!(&e)?;
                    if got != shape {
                        return Err(err(id, format!(
                            "input '{}' has shape {got:?}, expected {shape:?}",
                            vnode.name
                        )));
                    }
                } else {
                    match vars.get(&vnode.name) {
                        Some(prev) if *prev != shape => {
                            return Err(err(id, format!(
                                "variable '{}' inferred as {shape:?} but already {prev:?}",
                                vnode.name
                            )));
                        }
                        _ => {
                            vars.insert(vnode.name.clone(), shape.clone());
                        }
                    }
                    shapes[e.node] = vec![shape];
                }
            }};
        }

        let out: Vec<Vec<usize>> = match &node.op {
            Op::Variable => {
                match vars.get(&node.name) {
                    Some(s) => vec![s.clone()],
                    None => vec![], // solved later by a consumer (set_var!)
                }
            }
            Op::FullyConnected { num_hidden, .. } => {
                let x = get!(&node.inputs[0])?;
                let in_dim: usize = x[1..].iter().product();
                set_var!(node.inputs[1], vec![*num_hidden, in_dim]);
                set_var!(node.inputs[2], vec![*num_hidden]);
                vec![vec![x[0], *num_hidden]]
            }
            Op::Convolution { num_filter, kernel, stride, pad, .. } => {
                let x = get!(&node.inputs[0])?;
                if x.len() != 4 {
                    return Err(err(id, format!("conv input must be NCHW, got {x:?}")));
                }
                set_var!(node.inputs[1], vec![*num_filter, x[1], *kernel, *kernel]);
                set_var!(node.inputs[2], vec![*num_filter]);
                let oh = conv_out(x[2], *kernel, *stride, *pad);
                let ow = conv_out(x[3], *kernel, *stride, *pad);
                if oh == 0 || ow == 0 {
                    return Err(err(id, format!("conv output collapses to zero from {x:?}")));
                }
                vec![vec![x[0], *num_filter, oh, ow]]
            }
            Op::BatchNorm { .. } => {
                let x = get!(&node.inputs[0])?;
                let c = if x.len() >= 2 { x[1] } else { x[0] };
                set_var!(node.inputs[1], vec![c]);
                set_var!(node.inputs[2], vec![c]);
                vec![x.clone(), vec![c], vec![c]]
            }
            Op::SoftmaxOutput => {
                let x = get!(&node.inputs[0])?;
                set_var!(node.inputs[1], vec![x[0]]);
                vec![x]
            }
            Op::Activation { .. }
            | Op::AddScalar { .. }
            | Op::MulScalar { .. }
            | Op::Identity => vec![get!(&node.inputs[0])?],
            Op::Pooling { kernel, stride, pad, .. } => {
                let x = get!(&node.inputs[0])?;
                if x.len() != 4 {
                    return Err(err(id, format!("pool input must be NCHW, got {x:?}")));
                }
                let o = vec![
                    x[0],
                    x[1],
                    conv_out(x[2], *kernel, *stride, *pad),
                    conv_out(x[3], *kernel, *stride, *pad),
                ];
                vec![o.clone(), o]
            }
            Op::Flatten => {
                let x = get!(&node.inputs[0])?;
                vec![vec![x[0], x[1..].iter().product()]]
            }
            Op::Dropout { .. } => {
                let x = get!(&node.inputs[0])?;
                vec![x.clone(), x]
            }
            Op::Elemwise { .. } | Op::AddN => vec![get!(&node.inputs[0])?],
            Op::Concat => {
                let first = get!(&node.inputs[0])?;
                let mut ch = first[1];
                for e in &node.inputs[1..] {
                    ch += get!(e)?[1];
                }
                let mut o = first;
                o[1] = ch;
                vec![o]
            }
            other => {
                return Err(err(id, format!(
                    "unsupported op {:?} in forward model graph",
                    other.type_name()
                )));
            }
        };
        if !node.op.is_variable() || !out.is_empty() {
            shapes[id] = out;
        }
    }

    // Any variable never reached by a consumer is unresolvable.
    for vid in graph.variables() {
        let name = &graph.nodes[vid].name;
        if !vars.contains_key(name) {
            return Err(Error::shape(format!(
                "variable '{name}' not solvable from data shape"
            )));
        }
    }
    Ok(vars)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    /// Every zoo model must (a) solve all parameter shapes from the data
    /// shape alone and (b) agree with the strict `infer_shapes` pass.
    #[test]
    fn zoo_models_shape_check() {
        for spec in ["mlp", "alexnet", "vgg-11", "vgg-16", "inception-bn", "simple-cnn"] {
            let m = by_name(spec).unwrap();
            let (g, vs) = m.graph(4).unwrap();
            g.validate().unwrap();
            let shapes = infer_shapes(&g, &vs)
                .unwrap_or_else(|e| panic!("{spec}: {e}"));
            let out = g.outputs[0];
            assert_eq!(
                shapes[out.node][out.out],
                vec![4, m.num_classes],
                "{spec} head shape"
            );
        }
    }

    #[test]
    fn param_counts_in_expected_range() {
        // Sanity: published parameter counts (fc-dominated nets match
        // loosely since we keep the classic layouts).
        let alex = by_name("alexnet").unwrap().num_params().unwrap();
        assert!((50_000_000..70_000_000).contains(&alex), "alexnet {alex}");
        let vgg11 = by_name("vgg-11").unwrap().num_params().unwrap();
        assert!((120_000_000..140_000_000).contains(&vgg11), "vgg11 {vgg11}");
        let inc = by_name("inception-bn").unwrap().num_params().unwrap();
        assert!((10_000_000..20_000_000).contains(&inc), "inception {inc}");
    }

    #[test]
    fn scaled_input_spec() {
        let m = by_name("alexnet@64").unwrap();
        assert_eq!(m.feat_shape, vec![3, 64, 64]);
        m.param_shapes(2).unwrap();
    }

    #[test]
    fn servable_entry_points_are_row_pure() {
        // Serving entry points must never contain batch-statistics ops
        // (BatchNorm), which would break response-level losslessness.
        for m in [servable_mlp(784, 10), servable_alexnet(10)] {
            let g = Symbol::to_graph(std::slice::from_ref(&m.symbol));
            assert!(
                !g.nodes.iter().any(|n| matches!(n.op, Op::BatchNorm { .. })),
                "{} contains BatchNorm",
                m.name
            );
            m.param_shapes(4).unwrap();
        }
        assert_eq!(servable_mlp(784, 10).feat_shape, vec![784]);
        assert_eq!(servable_alexnet(10).feat_shape, vec![3, 64, 64]);
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(by_name("resnet-9000").is_err());
        assert!(by_name("alexnet@notanum").is_err());
    }

    #[test]
    fn unsolvable_variable_detected() {
        // A variable consumed only by Elemwise can't be solved.
        let a = Symbol::var("data");
        let b = Symbol::var("mystery");
        let c = &a + &b;
        let g = Symbol::to_graph(&[c]);
        assert!(infer_param_shapes(&g, &[4, 4]).is_err());
    }
}

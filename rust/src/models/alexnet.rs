//! AlexNet (one-tower variant, as in soumith/convnet-benchmarks) — one of
//! the Figure 6/7 workloads.

use super::Model;
use crate::symbol::{Act, Pool, Symbol};

/// AlexNet on `hw`x`hw` RGB input (224 reproduces the paper's setting;
/// smaller values keep topology but shrink spatial extent for CPU-budget
/// benches — DESIGN §4).
///
/// For small inputs the stride-4 stem and the three 3x2 pools need the
/// spatial size to survive; `hw >= 32` is required.
pub fn alexnet(num_classes: usize, hw: usize) -> Model {
    assert!(hw >= 32, "alexnet needs input >= 32x32, got {hw}");
    let out = Symbol::var("data")
        .convolution("conv1", 64, 11, 4, 2)
        .activation("relu1", Act::Relu)
        .pooling("pool1", Pool::Max, 3, 2, 0)
        .convolution("conv2", 192, 5, 1, 2)
        .activation("relu2", Act::Relu)
        .pooling("pool2", Pool::Max, 3, 2, 0)
        .convolution("conv3", 384, 3, 1, 1)
        .activation("relu3", Act::Relu)
        .convolution("conv4", 256, 3, 1, 1)
        .activation("relu4", Act::Relu)
        .convolution("conv5", 256, 3, 1, 1)
        .activation("relu5", Act::Relu)
        .pooling("pool5", Pool::Max, 3, 2, 0)
        .flatten("flat")
        .fully_connected("fc6", 4096)
        .activation("relu6", Act::Relu)
        .dropout("drop6", 0.5)
        .fully_connected("fc7", 4096)
        .activation("relu7", Act::Relu)
        .dropout("drop7", 0.5)
        .fully_connected("fc8", num_classes)
        .softmax_output("softmax");
    Model {
        name: format!("alexnet@{hw}"),
        symbol: out,
        feat_shape: vec![3, hw, hw],
        num_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_224_classic_shapes() {
        let m = alexnet(1000, 224);
        let ps = m.param_shapes(32).unwrap();
        assert_eq!(ps["conv1_weight"], vec![64, 3, 11, 11]);
        assert_eq!(ps["conv2_weight"], vec![192, 64, 5, 5]);
        // 224 -> conv/4 55 -> pool 27 -> pool 13 -> pool 6
        assert_eq!(ps["fc6_weight"], vec![4096, 256 * 6 * 6]);
        assert_eq!(ps["fc8_weight"], vec![1000, 4096]);
    }

    #[test]
    fn alexnet_scales_down() {
        let m = alexnet(10, 64);
        let ps = m.param_shapes(4).unwrap();
        assert_eq!(ps["conv1_weight"], vec![64, 3, 11, 11]);
        assert!(ps["fc6_weight"][1] > 0);
    }

    #[test]
    #[should_panic(expected = "needs input")]
    fn alexnet_rejects_tiny_input() {
        alexnet(10, 16);
    }
}

//! `Symbol` — declarative symbolic expressions (paper §2.1).
//!
//! Symbols are immutable expression nodes composed by operators; chaining
//! layer constructors reproduces the paper's Figure 2 MLP:
//!
//! ```
//! use mixnet::symbol::{Act, Symbol};
//! let mlp = Symbol::var("data")
//!     .fully_connected("fc1", 64)
//!     .activation("relu1", Act::Relu)
//!     .fully_connected("fc2", 10)
//!     .softmax_output("softmax");
//! assert!(mlp.list_arguments().contains(&"fc1_weight".to_string()));
//! ```
//!
//! Layer constructors implicitly create the parameter variables
//! (`{name}_weight`, `{name}_bias`, ...) exactly like MXNet.  Binding a
//! symbol converts the shared expression DAG into a [`Graph`] via
//! hash-consing on node identity ([`Symbol::to_graph`]).

use std::collections::HashMap;
use std::sync::Arc;

use crate::graph::{Entry, Graph, NodeId, Op};
use crate::ndarray::kernels::{EwBinary, PoolKind};

pub use crate::ndarray::kernels::ActKind as Act;
pub use crate::ndarray::kernels::PoolKind as Pool;

struct SymNode {
    op: Op,
    name: String,
    inputs: Vec<Symbol>,
}

/// A node in the symbolic expression DAG (cheap to clone; shares the
/// underlying expression).
#[derive(Clone)]
pub struct Symbol {
    node: Arc<SymNode>,
    out: usize,
}

impl std::fmt::Debug for Symbol {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Symbol({}:{})", self.node.name, self.out)
    }
}

impl Symbol {
    fn apply(op: Op, name: impl Into<String>, inputs: Vec<Symbol>) -> Symbol {
        Symbol { node: Arc::new(SymNode { op, name: name.into(), inputs }), out: 0 }
    }

    /// Select another output of a multi-output node.
    pub fn output(&self, out: usize) -> Symbol {
        Symbol { node: Arc::clone(&self.node), out }
    }

    /// Free variable (paper: `mx.Variable(:data)`).
    pub fn var(name: impl Into<String>) -> Symbol {
        Symbol::apply(Op::Variable, name, vec![])
    }

    /// Name of this symbol's node.
    pub fn name(&self) -> &str {
        &self.node.name
    }

    // ------------------------------------------------------------------
    // layer constructors (implicit parameter variables, MXNet-style)
    // ------------------------------------------------------------------

    /// Fully-connected layer; creates `{name}_weight` and `{name}_bias`.
    pub fn fully_connected(&self, name: &str, num_hidden: usize) -> Symbol {
        let w = Symbol::var(format!("{name}_weight"));
        let b = Symbol::var(format!("{name}_bias"));
        Symbol::apply(
            Op::FullyConnected { num_hidden, epilogue: vec![] },
            name,
            vec![self.clone(), w, b],
        )
    }

    /// Square convolution; creates `{name}_weight` and `{name}_bias`.
    pub fn convolution(
        &self,
        name: &str,
        num_filter: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> Symbol {
        let w = Symbol::var(format!("{name}_weight"));
        let b = Symbol::var(format!("{name}_bias"));
        Symbol::apply(
            Op::Convolution { num_filter, kernel, stride, pad, epilogue: vec![] },
            name,
            vec![self.clone(), w, b],
        )
    }

    /// Elementwise activation (paper: `mx.Activation(act_type=:relu)`).
    pub fn activation(&self, name: &str, kind: Act) -> Symbol {
        Symbol::apply(Op::Activation { kind }, name, vec![self.clone()])
    }

    /// Square pooling.
    pub fn pooling(&self, name: &str, kind: PoolKind, kernel: usize, stride: usize, pad: usize) -> Symbol {
        Symbol::apply(Op::Pooling { kind, kernel, stride, pad }, name, vec![self.clone()])
    }

    /// Batch normalization; creates `{name}_gamma` and `{name}_beta`.
    pub fn batch_norm(&self, name: &str) -> Symbol {
        let gamma = Symbol::var(format!("{name}_gamma"));
        let beta = Symbol::var(format!("{name}_beta"));
        Symbol::apply(Op::BatchNorm { eps: 1e-5 }, name, vec![self.clone(), gamma, beta])
    }

    /// Collapse to 2-d `[batch, features]`.
    pub fn flatten(&self, name: &str) -> Symbol {
        Symbol::apply(Op::Flatten, name, vec![self.clone()])
    }

    /// Dropout with drop probability `p`.
    pub fn dropout(&self, name: &str, p: f32) -> Symbol {
        Symbol::apply(Op::Dropout { p, seed: 0xd06 }, name, vec![self.clone()])
    }

    /// Softmax + cross-entropy head; creates the `{name}_label` variable.
    pub fn softmax_output(&self, name: &str) -> Symbol {
        let label = Symbol::var(format!("{name}_label"));
        self.softmax_output_with_label(name, &label)
    }

    /// Softmax head with an explicit label symbol.
    pub fn softmax_output_with_label(&self, name: &str, label: &Symbol) -> Symbol {
        Symbol::apply(Op::SoftmaxOutput, name, vec![self.clone(), label.clone()])
    }

    /// Channel concat (the Inception merge).
    pub fn concat(name: &str, parts: &[Symbol]) -> Symbol {
        assert!(!parts.is_empty());
        Symbol::apply(Op::Concat, name, parts.to_vec())
    }

    /// `self + s`.
    pub fn add_scalar(&self, name: &str, s: f32) -> Symbol {
        Symbol::apply(Op::AddScalar { s }, name, vec![self.clone()])
    }

    /// `self * s`.
    pub fn mul_scalar(&self, name: &str, s: f32) -> Symbol {
        Symbol::apply(Op::MulScalar { s }, name, vec![self.clone()])
    }

    fn elemwise(&self, other: &Symbol, op: EwBinary, name: &str) -> Symbol {
        Symbol::apply(Op::Elemwise { op }, name, vec![self.clone(), other.clone()])
    }

    // ------------------------------------------------------------------
    // binding support
    // ------------------------------------------------------------------

    /// Convert symbol DAG(s) to a [`Graph`].  Shared subexpressions are
    /// deduplicated by node identity.  Returns the graph with `heads` as
    /// its outputs.
    pub fn to_graph(heads: &[Symbol]) -> Graph {
        let mut graph = Graph::new();
        let mut memo: HashMap<*const SymNode, NodeId> = HashMap::new();
        fn lower(
            sym: &Symbol,
            graph: &mut Graph,
            memo: &mut HashMap<*const SymNode, NodeId>,
        ) -> NodeId {
            let key = Arc::as_ptr(&sym.node);
            if let Some(&id) = memo.get(&key) {
                return id;
            }
            let inputs: Vec<Entry> = sym
                .node
                .inputs
                .iter()
                .map(|s| Entry { node: lower(s, graph, memo), out: s.out })
                .collect();
            let id = graph.add_node(sym.node.op.clone(), sym.node.name.clone(), inputs);
            memo.insert(key, id);
            id
        }
        let outputs: Vec<Entry> = heads
            .iter()
            .map(|h| Entry { node: lower(h, &mut graph, &mut memo), out: h.out })
            .collect();
        graph.outputs = outputs;
        graph.num_forward = graph.nodes.len();
        graph
    }

    /// Names of all argument variables in depth-first order (paper's
    /// `list_arguments`).
    pub fn list_arguments(&self) -> Vec<String> {
        let g = Symbol::to_graph(std::slice::from_ref(self));
        g.variables().into_iter().map(|id| g.nodes[id].name.clone()).collect()
    }
}

impl std::ops::Add for &Symbol {
    type Output = Symbol;
    fn add(self, rhs: Self) -> Symbol {
        self.elemwise(rhs, EwBinary::Add, "_add")
    }
}

impl std::ops::Sub for &Symbol {
    type Output = Symbol;
    fn sub(self, rhs: Self) -> Symbol {
        self.elemwise(rhs, EwBinary::Sub, "_sub")
    }
}

impl std::ops::Mul for &Symbol {
    type Output = Symbol;
    fn mul(self, rhs: Self) -> Symbol {
        self.elemwise(rhs, EwBinary::Mul, "_mul")
    }
}

impl std::ops::Div for &Symbol {
    type Output = Symbol;
    fn div(self, rhs: Self) -> Symbol {
        self.elemwise(rhs, EwBinary::Div, "_div")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure2_mlp_arguments() {
        let mlp = Symbol::var("data")
            .fully_connected("fc1", 64)
            .activation("relu1", Act::Relu)
            .fully_connected("fc2", 10)
            .softmax_output("softmax");
        let args = mlp.list_arguments();
        assert_eq!(
            args,
            vec![
                "data",
                "fc1_weight",
                "fc1_bias",
                "fc2_weight",
                "fc2_bias",
                "softmax_label"
            ]
        );
    }

    #[test]
    fn shared_subexpression_deduplicated() {
        let x = Symbol::var("x");
        let y = x.add_scalar("y", 1.0);
        let z = &y + &y; // y appears twice but must lower once
        let g = Symbol::to_graph(&[z]);
        let count = g.nodes.iter().filter(|n| n.name == "y").count();
        assert_eq!(count, 1);
        g.validate().unwrap();
    }

    #[test]
    fn multi_output_selection() {
        let x = Symbol::var("x");
        let pool = x.pooling("p", Pool::Max, 2, 2, 0);
        let mask = pool.output(1);
        let g = Symbol::to_graph(&[pool.clone(), mask]);
        assert_eq!(g.outputs[0].out, 0);
        assert_eq!(g.outputs[1].out, 1);
        assert_eq!(g.outputs[0].node, g.outputs[1].node);
    }

    #[test]
    fn operator_sugar_builds_elemwise() {
        let a = Symbol::var("a");
        let b = Symbol::var("b");
        let c = &(&a * &b) + &a;
        let g = Symbol::to_graph(&[c]);
        assert!(g.nodes.iter().any(|n| matches!(n.op, Op::Elemwise { op: EwBinary::Mul })));
        assert!(g.nodes.iter().any(|n| matches!(n.op, Op::Elemwise { op: EwBinary::Add })));
    }
}

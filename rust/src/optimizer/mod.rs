//! Optimizers (paper §2.4: *"the training module implements the commonly
//! used optimization algorithms, such as stochastic gradient descent"*).
//!
//! Updates are expressed as in-place engine operations on the weight
//! arrays (`w -= eta * g` style), so they schedule jointly with graph
//! execution and KVStore traffic.  An [`Optimizer`] is also what you
//! register as a [`KVStore`](crate::kvstore) *updater* for data-parallel
//! training.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::engine::EngineRef;
use crate::ndarray::NDArray;

/// One exported per-key optimizer buffer: (state key, shape, data).
/// State keys are namespaced by the optimizer (`vel:`, `adam.m:`, ...)
/// so heterogeneous state survives a round trip unambiguously.
pub type StateBlob = (String, Vec<usize>, Vec<f32>);

/// A stateful parameter optimizer.
pub trait Optimizer: Send + Sync {
    /// Apply one update: mutate `weight` given `grad`.  `key` identifies
    /// the parameter so the optimizer can keep per-key state (momentum,
    /// moments).
    fn update(&self, key: &str, weight: &NDArray, grad: &NDArray);

    /// Current learning rate (for logging).
    fn learning_rate(&self) -> f32;

    /// Override the learning rate (scheduling).
    fn set_learning_rate(&self, lr: f32);

    /// Export per-key state for checkpointing, sorted by state key so
    /// the byte stream is deterministic.  Stateless optimizers export
    /// nothing (the default) — for them resume-exactness is free.
    fn export_state(&self) -> Vec<StateBlob> {
        Vec::new()
    }

    /// Restore state previously produced by
    /// [`export_state`](Optimizer::export_state).  Blobs the optimizer
    /// does not recognize are ignored (forward compatibility); the
    /// default is a no-op for stateless optimizers.
    fn import_state(&self, _state: &[StateBlob], _engine: &EngineRef) {}
}

/// SGD with momentum and weight decay — the configuration of the paper's
/// scalability experiment (lr=.05, momentum=.9, wd=1e-4).
pub struct Sgd {
    lr: Mutex<f32>,
    /// Momentum coefficient (0 disables the velocity buffer).
    pub momentum: f32,
    /// L2 weight decay.
    pub weight_decay: f32,
    /// Gradient rescale (e.g. 1/num_workers for aggregated gradients).
    pub rescale: f32,
    state: Mutex<HashMap<String, NDArray>>,
}

impl Sgd {
    /// Plain SGD.
    pub fn new(lr: f32) -> Self {
        Sgd {
            lr: Mutex::new(lr),
            momentum: 0.0,
            weight_decay: 0.0,
            rescale: 1.0,
            state: Mutex::new(HashMap::new()),
        }
    }

    /// SGD with momentum + weight decay (paper's settings).
    pub fn with_momentum(lr: f32, momentum: f32, weight_decay: f32) -> Self {
        Sgd { momentum, weight_decay, ..Sgd::new(lr) }
    }

    /// Set gradient rescale factor.
    pub fn rescale(mut self, r: f32) -> Self {
        self.rescale = r;
        self
    }
}

impl Optimizer for Sgd {
    fn update(&self, key: &str, weight: &NDArray, grad: &NDArray) {
        let lr = *self.lr.lock().unwrap();
        let (mom, wd, rescale) = (self.momentum, self.weight_decay, self.rescale);
        if mom == 0.0 {
            // w -= lr * (rescale*g + wd*w): one fused engine op.
            let (ws, gs) = (weight.storage(), grad.storage());
            weight.engine().push(
                "sgd.update",
                vec![grad.var()],
                vec![weight.var()],
                Box::new(move || unsafe {
                    let w = ws.slice_mut();
                    let g = gs.slice();
                    for i in 0..w.len() {
                        w[i] -= lr * (rescale * g[i] + wd * w[i]);
                    }
                }),
            );
        } else {
            let mut state = self.state.lock().unwrap();
            let vel = state
                .entry(key.to_string())
                .or_insert_with(|| NDArray::zeros_on(weight.shape(), weight.engine()))
                .clone();
            drop(state);
            // v = mom*v - lr*(rescale*g + wd*w); w += v
            let (ws, gs, vs) = (weight.storage(), grad.storage(), vel.storage());
            weight.engine().push(
                "sgd.momentum_update",
                vec![grad.var()],
                vec![weight.var(), vel.var()],
                Box::new(move || unsafe {
                    let w = ws.slice_mut();
                    let g = gs.slice();
                    let v = vs.slice_mut();
                    for i in 0..w.len() {
                        v[i] = mom * v[i] - lr * (rescale * g[i] + wd * w[i]);
                        w[i] += v[i];
                    }
                }),
            );
        }
    }

    fn learning_rate(&self) -> f32 {
        *self.lr.lock().unwrap()
    }

    fn set_learning_rate(&self, lr: f32) {
        *self.lr.lock().unwrap() = lr;
    }

    fn export_state(&self) -> Vec<StateBlob> {
        let state = self.state.lock().unwrap();
        let mut out: Vec<StateBlob> = state
            .iter()
            .map(|(k, v)| (format!("vel:{k}"), v.shape().to_vec(), v.to_vec()))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    fn import_state(&self, blobs: &[StateBlob], engine: &EngineRef) {
        let mut state = self.state.lock().unwrap();
        for (name, shape, data) in blobs {
            if let Some(key) = name.strip_prefix("vel:") {
                let v = NDArray::from_vec_on(shape, data.clone(), engine.clone());
                state.insert(key.to_string(), v);
            }
        }
    }
}

/// Adam optimizer (per-key first/second moment state).
pub struct Adam {
    lr: Mutex<f32>,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Numerical epsilon.
    pub eps: f32,
    state: Mutex<HashMap<String, (NDArray, NDArray, u64)>>,
}

impl Adam {
    /// Adam with standard betas.
    pub fn new(lr: f32) -> Self {
        Adam {
            lr: Mutex::new(lr),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            state: Mutex::new(HashMap::new()),
        }
    }
}

impl Optimizer for Adam {
    fn update(&self, key: &str, weight: &NDArray, grad: &NDArray) {
        let lr = *self.lr.lock().unwrap();
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let mut state = self.state.lock().unwrap();
        let entry = state.entry(key.to_string()).or_insert_with(|| {
            (
                NDArray::zeros_on(weight.shape(), weight.engine()),
                NDArray::zeros_on(weight.shape(), weight.engine()),
                0,
            )
        });
        entry.2 += 1;
        let t = entry.2;
        let (m, v) = (entry.0.clone(), entry.1.clone());
        drop(state);
        let (ws, gs, ms, vs) = (weight.storage(), grad.storage(), m.storage(), v.storage());
        weight.engine().push(
            "adam.update",
            vec![grad.var()],
            vec![weight.var(), m.var(), v.var()],
            Box::new(move || unsafe {
                let w = ws.slice_mut();
                let g = gs.slice();
                let m = ms.slice_mut();
                let v = vs.slice_mut();
                let bc1 = 1.0 - b1.powi(t as i32);
                let bc2 = 1.0 - b2.powi(t as i32);
                for i in 0..w.len() {
                    m[i] = b1 * m[i] + (1.0 - b1) * g[i];
                    v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
                    let mhat = m[i] / bc1;
                    let vhat = v[i] / bc2;
                    w[i] -= lr * mhat / (vhat.sqrt() + eps);
                }
            }),
        );
    }

    fn learning_rate(&self) -> f32 {
        *self.lr.lock().unwrap()
    }

    fn set_learning_rate(&self, lr: f32) {
        *self.lr.lock().unwrap() = lr;
    }

    fn export_state(&self) -> Vec<StateBlob> {
        let state = self.state.lock().unwrap();
        let mut out: Vec<StateBlob> = Vec::new();
        for (k, (m, v, t)) in state.iter() {
            out.push((format!("adam.m:{k}"), m.shape().to_vec(), m.to_vec()));
            // the step count rides along bit-exactly as two f32 halves
            out.push((
                format!("adam.t:{k}"),
                vec![2],
                vec![f32::from_bits(*t as u32), f32::from_bits((*t >> 32) as u32)],
            ));
            out.push((format!("adam.v:{k}"), v.shape().to_vec(), v.to_vec()));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    fn import_state(&self, blobs: &[StateBlob], engine: &EngineRef) {
        let mut state = self.state.lock().unwrap();
        for (name, shape, data) in blobs {
            let fresh = || {
                (
                    NDArray::zeros_on(shape, engine.clone()),
                    NDArray::zeros_on(shape, engine.clone()),
                    0u64,
                )
            };
            if let Some(key) = name.strip_prefix("adam.m:") {
                let e = state.entry(key.to_string()).or_insert_with(fresh);
                e.0 = NDArray::from_vec_on(shape, data.clone(), engine.clone());
            } else if let Some(key) = name.strip_prefix("adam.v:") {
                let e = state.entry(key.to_string()).or_insert_with(fresh);
                e.1 = NDArray::from_vec_on(shape, data.clone(), engine.clone());
            } else if let Some(key) = name.strip_prefix("adam.t:") {
                if data.len() == 2 {
                    let e = state.entry(key.to_string()).or_insert_with(fresh);
                    e.2 = u64::from(data[0].to_bits()) | (u64::from(data[1].to_bits()) << 32);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_plain_matches_formula() {
        let w = NDArray::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let g = NDArray::from_vec(&[3], vec![0.5, 0.5, 0.5]);
        let opt = Sgd::new(0.1);
        opt.update("w", &w, &g);
        let got = w.to_vec();
        for (x, want) in got.iter().zip([0.95, 1.95, 2.95]) {
            assert!((x - want).abs() < 1e-6);
        }
    }

    #[test]
    fn sgd_momentum_accumulates_velocity() {
        let w = NDArray::zeros(&[1]);
        let g = NDArray::ones(&[1]);
        let opt = Sgd::with_momentum(0.1, 0.9, 0.0);
        opt.update("w", &w, &g); // v=-0.1, w=-0.1
        opt.update("w", &w, &g); // v=-0.19, w=-0.29
        let got = w.to_vec()[0];
        assert!((got + 0.29).abs() < 1e-5, "{got}");
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let w = NDArray::from_vec(&[1], vec![10.0]);
        let g = NDArray::zeros(&[1]);
        let opt = Sgd::with_momentum(0.1, 0.0, 0.01);
        opt.update("w", &w, &g);
        let got = w.to_vec()[0];
        assert!(got < 10.0 && got > 9.9, "{got}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize f(w) = (w-3)^2 with grad 2(w-3)
        let w = NDArray::zeros(&[1]);
        let opt = Adam::new(0.2);
        for _ in 0..200 {
            let cur = w.to_vec()[0];
            let g = NDArray::from_vec(&[1], vec![2.0 * (cur - 3.0)]);
            opt.update("w", &w, &g);
        }
        let got = w.to_vec()[0];
        assert!((got - 3.0).abs() < 0.1, "{got}");
    }

    #[test]
    fn lr_schedule_applied() {
        let opt = Sgd::new(0.1);
        opt.set_learning_rate(0.01);
        assert_eq!(opt.learning_rate(), 0.01);
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn sgd_state_roundtrips_bitwise() {
        // Two optimizers, one restored from the other's exported state,
        // must continue bitwise identically.
        let w1 = NDArray::zeros(&[2]);
        let g = NDArray::ones(&[2]);
        let opt = Sgd::with_momentum(0.1, 0.9, 1e-4);
        opt.update("w", &w1, &g);
        opt.update("w", &w1, &g);
        let blobs = opt.export_state();
        assert_eq!(blobs.len(), 1);
        assert_eq!(blobs[0].0, "vel:w");
        let w2 = NDArray::from_vec(&[2], w1.to_vec());
        let opt2 = Sgd::with_momentum(0.1, 0.9, 1e-4);
        opt2.import_state(&blobs, &w2.engine());
        opt.update("w", &w1, &g);
        opt2.update("w", &w2, &g);
        assert_eq!(bits(&w1.to_vec()), bits(&w2.to_vec()));
    }

    #[test]
    fn adam_state_roundtrips_bitwise() {
        let w1 = NDArray::zeros(&[2]);
        let g = NDArray::ones(&[2]);
        let opt = Adam::new(0.05);
        for _ in 0..3 {
            opt.update("w", &w1, &g);
        }
        let blobs = opt.export_state();
        assert_eq!(blobs.len(), 3, "m, t, v per key");
        let w2 = NDArray::from_vec(&[2], w1.to_vec());
        let opt2 = Adam::new(0.05);
        opt2.import_state(&blobs, &w2.engine());
        // the step counter must survive exactly, or bias correction drifts
        opt.update("w", &w1, &g);
        opt2.update("w", &w2, &g);
        assert_eq!(bits(&w1.to_vec()), bits(&w2.to_vec()));
    }

    #[test]
    fn per_key_state_is_independent() {
        let opt = Sgd::with_momentum(0.1, 0.9, 0.0);
        let w1 = NDArray::zeros(&[1]);
        let w2 = NDArray::zeros(&[1]);
        let g = NDArray::ones(&[1]);
        opt.update("a", &w1, &g);
        opt.update("a", &w1, &g);
        opt.update("b", &w2, &g);
        // b only took one step: velocity fresh
        assert!((w2.to_vec()[0] + 0.1).abs() < 1e-6);
        assert!((w1.to_vec()[0] + 0.29).abs() < 1e-5);
    }
}

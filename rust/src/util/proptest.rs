//! A miniature property-based testing helper (`proptest` is not vendored).
//!
//! [`check`] runs a property against many seeded-random cases and, on
//! failure, reports the seed so the case can be replayed deterministically.
//! Generators are plain closures over [`Rng`], which keeps shrinking out of
//! scope but preserves the essential property-testing workflow: random
//! exploration + reproducible counterexamples.

use super::rng::Rng;

/// Run `prop` against `cases` random inputs drawn by `gen`.
///
/// Panics with the failing seed and debug-printed input on the first
/// counterexample.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base = 0x6d69786e65742121u64; // deterministic base seed
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::seed_from_u64(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): input = {input:?}");
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` so failures
/// can carry an explanation.
pub fn check_explain<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> std::result::Result<(), String>,
) {
    let base = 0x6d69786e65742121u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::seed_from_u64(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\ninput = {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 100, |r| (r.below(1000) as i64, r.below(1000) as i64), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_reports() {
        check("always-false", 10, |r| r.below(10), |_| false);
    }
}

//! Small self-contained substrates: PRNG, thread pool, bench harness and a
//! mini property-testing helper.  These exist because `mixnet` is
//! deliberately dependency-light (the paper: *"no other dependency"*).

pub mod args;
pub mod bench;
pub mod proptest;
pub mod rng;
pub mod threadpool;

pub use args::Args;
pub use rng::Rng;
pub use threadpool::{
    intra_budget, intra_pool, parallel_for, parallel_for_cost, set_intra_budget,
    with_intra_budget, IntraPool, ThreadPool, INTRA_MIN_COST,
};

/// Format a byte count as a human-readable MB string (as used by Figure 7).
pub fn mb(bytes: usize) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

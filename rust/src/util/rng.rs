//! A small, fast, seedable PRNG (xoshiro256++) plus normal sampling.
//!
//! The crate needs randomness for weight init, data synthesis and the
//! property tests; `rand` is not vendored, so we carry the 30 lines
//! ourselves.  xoshiro256++ is the same generator family `rand`'s
//! `SmallRng` uses.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample from Box–Muller.
    spare: Option<f32>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64, used to expand a single seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a PRNG from a 64-bit seed.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 high bits -> f32 mantissa range.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (caches the spare sample).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with explicit mean / std.
    #[inline]
    pub fn normal_with(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let samples: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f32>() / n as f32;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::seed_from_u64(9);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}

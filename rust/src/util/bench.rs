//! A small benchmark harness (criterion is not vendored in this image).
//!
//! The `cargo bench` targets under `rust/benches/` are `harness = false`
//! binaries built on this module: warmup, repeated timed runs, and
//! median/mean/stddev reporting, plus aligned-table printing used to
//! regenerate the paper's figures as text tables.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Case label.
    pub name: String,
    /// Per-iteration wall times.
    pub samples: Vec<Duration>,
}

impl BenchStats {
    /// Median per-iteration time in seconds.
    pub fn median_s(&self) -> f64 {
        let mut v: Vec<f64> = self.samples.iter().map(|d| d.as_secs_f64()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if v.is_empty() {
            return f64::NAN;
        }
        let mid = v.len() / 2;
        if v.len() % 2 == 0 {
            (v[mid - 1] + v[mid]) / 2.0
        } else {
            v[mid]
        }
    }

    /// Mean per-iteration time in seconds.
    pub fn mean_s(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation in seconds.
    pub fn stddev_s(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean_s();
        let var = self
            .samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean;
                x * x
            })
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    /// Median in milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.median_s() * 1e3
    }
}

/// Benchmark runner: warms up then collects `samples` timed iterations of
/// `f`, bounding total time by `max_total`.
pub struct Bencher {
    /// Number of warmup iterations (not recorded).
    pub warmup: usize,
    /// Target number of recorded samples.
    pub samples: usize,
    /// Total time budget per case; sampling stops early when exceeded.
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, samples: 10, max_total: Duration::from_secs(20) }
    }
}

impl Bencher {
    /// Quick preset for cheap micro-benchmarks.
    pub fn micro() -> Self {
        Bencher { warmup: 10, samples: 50, max_total: Duration::from_secs(10) }
    }

    /// Run one case.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            f();
        }
        let start = Instant::now();
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            if start.elapsed() > self.max_total {
                break;
            }
        }
        let stats = BenchStats { name: name.to_string(), samples };
        eprintln!(
            "  {:<40} median {:>10.3} ms   mean {:>10.3} ms ± {:>7.3} ({} samples)",
            stats.name,
            stats.median_ms(),
            stats.mean_s() * 1e3,
            stats.stddev_s() * 1e3,
            stats.samples.len()
        );
        stats
    }
}

/// Print an aligned text table (used by the figure-regeneration benches).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate().take(ncol) {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        s
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    println!("{}", line(&hdr));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
    for row in rows {
        println!("{}", line(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        let mk = |ms: &[u64]| BenchStats {
            name: "t".into(),
            samples: ms.iter().map(|&m| Duration::from_millis(m)).collect(),
        };
        assert!((mk(&[1, 2, 3]).median_ms() - 2.0).abs() < 1e-9);
        assert!((mk(&[1, 2, 3, 4]).median_ms() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn stddev_zero_for_single_sample() {
        let s = BenchStats { name: "t".into(), samples: vec![Duration::from_millis(5)] };
        assert_eq!(s.stddev_s(), 0.0);
    }

    #[test]
    fn runner_collects_samples() {
        let b = Bencher { warmup: 1, samples: 5, max_total: Duration::from_secs(5) };
        let stats = b.run("noop", || { std::hint::black_box(1 + 1); });
        assert_eq!(stats.samples.len(), 5);
    }
}

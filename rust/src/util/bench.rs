//! A small benchmark harness (criterion is not vendored in this image).
//!
//! The `cargo bench` targets under `rust/benches/` are `harness = false`
//! binaries built on this module: warmup, repeated timed runs, and
//! median/mean/stddev reporting, plus aligned-table printing used to
//! regenerate the paper's figures as text tables.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Case label.
    pub name: String,
    /// Per-iteration wall times.
    pub samples: Vec<Duration>,
}

impl BenchStats {
    /// Median per-iteration time in seconds.
    pub fn median_s(&self) -> f64 {
        let mut v: Vec<f64> = self.samples.iter().map(|d| d.as_secs_f64()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if v.is_empty() {
            return f64::NAN;
        }
        let mid = v.len() / 2;
        if v.len() % 2 == 0 {
            (v[mid - 1] + v[mid]) / 2.0
        } else {
            v[mid]
        }
    }

    /// Mean per-iteration time in seconds.
    pub fn mean_s(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation in seconds.
    pub fn stddev_s(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean_s();
        let var = self
            .samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean;
                x * x
            })
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    /// Median in milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.median_s() * 1e3
    }
}

/// Benchmark runner: warms up then collects `samples` timed iterations of
/// `f`, bounding total time by `max_total`.
pub struct Bencher {
    /// Number of warmup iterations (not recorded).
    pub warmup: usize,
    /// Target number of recorded samples.
    pub samples: usize,
    /// Total time budget per case; sampling stops early when exceeded.
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, samples: 10, max_total: Duration::from_secs(20) }
    }
}

impl Bencher {
    /// Quick preset for cheap micro-benchmarks.
    pub fn micro() -> Self {
        Bencher { warmup: 10, samples: 50, max_total: Duration::from_secs(10) }
    }

    /// Run one case.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            f();
        }
        let start = Instant::now();
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            if start.elapsed() > self.max_total {
                break;
            }
        }
        let stats = BenchStats { name: name.to_string(), samples };
        eprintln!(
            "  {:<40} median {:>10.3} ms   mean {:>10.3} ms ± {:>7.3} ({} samples)",
            stats.name,
            stats.median_ms(),
            stats.mean_s() * 1e3,
            stats.stddev_s() * 1e3,
            stats.samples.len()
        );
        stats
    }
}

/// One machine-readable benchmark record for `BENCH_*.json` files.
///
/// Future PRs track the perf trajectory by diffing these files, so the
/// schema is deliberately flat: one object per (op, shape, threads)
/// combination.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Kernel / case name, e.g. "gemm" or "fig6/mxnet/forward".
    pub op: String,
    /// Shape string, e.g. "512x512x512".
    pub shape: String,
    /// Intra-op threads used (0 = not applicable).
    pub threads: usize,
    /// Median wall time per iteration, milliseconds.
    pub median_ms: f64,
    /// Achieved GFLOP/s (0.0 when no FLOP count applies).
    pub gflops: f64,
}

impl BenchRecord {
    /// Build a record from measured stats and a FLOP count per iteration.
    pub fn from_stats(op: &str, shape: &str, threads: usize, stats: &BenchStats, flops: f64) -> Self {
        let s = stats.median_s();
        BenchRecord {
            op: op.to_string(),
            shape: shape.to_string(),
            threads,
            median_ms: s * 1e3,
            gflops: if s > 0.0 && flops > 0.0 { flops / s / 1e9 } else { 0.0 },
        }
    }
}

/// The shared metadata block every `BENCH_*.json` carries: schema
/// version, bench name, git commit, intra-op thread knob, quick flag,
/// and a unix timestamp — enough for later PRs to diff bench files
/// across commits and machines without guessing the context.
pub fn standard_meta(bench: &str, quick: bool) -> Vec<(&'static str, String)> {
    let threads = std::env::var("PALLAS_INTRA_THREADS").unwrap_or_else(|_| "default".into());
    vec![
        ("schema_version", "1".to_string()),
        ("bench", bench.to_string()),
        ("git_sha", git_sha()),
        ("intra_threads", threads),
        ("quick", if quick { "1".to_string() } else { "0".to_string() }),
        ("unix_time", unix_time().to_string()),
    ]
}

/// Commit id: `GITHUB_SHA` in CI, `git rev-parse HEAD` locally,
/// "unknown" outside a checkout.
fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| "unknown".to_string())
}

fn unix_time() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Minimal JSON string escaping (the only non-trivial characters our
/// bench names can contain are quotes and backslashes).
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Serialize records as a pretty-printed JSON document (hand-rolled —
/// serde is not vendored).
pub fn bench_records_to_json(meta: &[(&str, String)], records: &[BenchRecord]) -> String {
    let mut out = String::from("{\n");
    for (k, v) in meta {
        out.push_str(&format!("  \"{}\": \"{}\",\n", json_escape(k), json_escape(v)));
    }
    out.push_str("  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"op\": \"{}\", \"shape\": \"{}\", \"threads\": {}, \
             \"median_ms\": {:.4}, \"gflops\": {:.3}}}{}\n",
            json_escape(&r.op),
            json_escape(&r.shape),
            r.threads,
            r.median_ms,
            r.gflops,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write records to `path` as JSON, with free-form metadata pairs
/// (date, host, commit, ...) at the top level.
pub fn write_bench_json(
    path: &str,
    meta: &[(&str, String)],
    records: &[BenchRecord],
) -> std::io::Result<()> {
    std::fs::write(path, bench_records_to_json(meta, records))?;
    eprintln!("wrote {} records to {path}", records.len());
    Ok(())
}

/// Print an aligned text table (used by the figure-regeneration benches).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate().take(ncol) {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        s
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    println!("{}", line(&hdr));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
    for row in rows {
        println!("{}", line(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        let mk = |ms: &[u64]| BenchStats {
            name: "t".into(),
            samples: ms.iter().map(|&m| Duration::from_millis(m)).collect(),
        };
        assert!((mk(&[1, 2, 3]).median_ms() - 2.0).abs() < 1e-9);
        assert!((mk(&[1, 2, 3, 4]).median_ms() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn stddev_zero_for_single_sample() {
        let s = BenchStats { name: "t".into(), samples: vec![Duration::from_millis(5)] };
        assert_eq!(s.stddev_s(), 0.0);
    }

    #[test]
    fn runner_collects_samples() {
        let b = Bencher { warmup: 1, samples: 5, max_total: Duration::from_secs(5) };
        let stats = b.run("noop", || { std::hint::black_box(1 + 1); });
        assert_eq!(stats.samples.len(), 5);
    }

    #[test]
    fn bench_record_computes_gflops() {
        let stats = BenchStats {
            name: "gemm".into(),
            samples: vec![Duration::from_millis(100); 3],
        };
        // 2e9 FLOP in 0.1 s = 20 GFLOP/s
        let r = BenchRecord::from_stats("gemm", "1024x1024x1024", 4, &stats, 2e9);
        assert!((r.gflops - 20.0).abs() < 1e-6, "{}", r.gflops);
        assert!((r.median_ms - 100.0).abs() < 1e-6);
    }

    #[test]
    fn json_output_is_well_formed() {
        let r = BenchRecord {
            op: "gemm".into(),
            shape: "8x8x8".into(),
            threads: 2,
            median_ms: 1.25,
            gflops: 3.5,
        };
        let js = bench_records_to_json(&[("bench", "kernels".to_string())], &[r]);
        assert!(js.contains("\"bench\": \"kernels\""));
        assert!(js.contains("\"op\": \"gemm\""));
        assert!(js.contains("\"threads\": 2"));
        assert!(js.starts_with('{') && js.trim_end().ends_with('}'));
        // no trailing comma before the closing bracket
        assert!(!js.contains(",\n  ]"));
    }

    #[test]
    fn json_escapes_quotes() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
    }
}

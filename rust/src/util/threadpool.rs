//! A fixed-size worker thread pool.
//!
//! The dependency engine dispatches ready operations onto this pool
//! (MXNet §3.2: *"the engine uses multiple threads to scheduling the
//! operations for better resource utilization and parallelization"*).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

struct Shared {
    rx: Mutex<mpsc::Receiver<Msg>>,
    /// Jobs submitted but not yet finished; guarded by `idle` for wait().
    inflight: AtomicUsize,
    idle: (Mutex<()>, Condvar),
}

/// Fixed-size thread pool with a `wait_idle` barrier.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size` worker threads (clamped to >= 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let shared = Arc::new(Shared {
            rx: Mutex::new(rx),
            inflight: AtomicUsize::new(0),
            idle: (Mutex::new(()), Condvar::new()),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("mixnet-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cvar) = &self.shared.idle;
        let mut guard = lock.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = cvar.wait(guard).unwrap();
        }
        drop(guard);
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let msg = {
            let rx = shared.rx.lock().unwrap();
            rx.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                job();
                let prev = shared.inflight.fetch_sub(1, Ordering::SeqCst);
                if prev == 1 {
                    let (lock, cvar) = &shared.idle;
                    let _g = lock.lock().unwrap();
                    cvar.notify_all();
                }
            }
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // The pool can be dropped *from one of its own workers* (the last
        // op closure may own the last Arc to the engine); joining oneself
        // would deadlock (EDEADLK), so that worker is detached instead —
        // it exits on the Shutdown message it already has queued.
        let me = std::thread::current().id();
        for w in self.workers.drain(..) {
            if w.thread().id() != me {
                let _ = w.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn jobs_can_submit_more_jobs() {
        let pool = Arc::new(ThreadPool::new(2));
        let counter = Arc::new(AtomicU64::new(0));
        // A job is not allowed to submit into the same pool it runs on
        // (the engine never does this either: completion callbacks run on
        // the scheduler side).  Submit from a separate thread instead.
        let (tx, rx) = mpsc::channel();
        {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        rx.recv().unwrap();
        {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }
}

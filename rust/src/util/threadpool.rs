//! Worker thread pools: the engine's inter-op pool and the kernels'
//! intra-op pool.
//!
//! [`ThreadPool`] backs the dependency engine, which dispatches ready
//! operations onto it (MXNet §3.2: *"the engine uses multiple threads to
//! scheduling the operations for better resource utilization and
//! parallelization"*).  That is **inter**-op parallelism: independent
//! kernels run concurrently.
//!
//! [`IntraPool`] / [`parallel_for`] provide **intra**-op parallelism: one
//! big kernel (a GEMM row-panel sweep, a batch of images through im2col)
//! splits its own index space into chunks and fans those out.  The chunk
//! partition is a pure function of the problem size — never of the thread
//! count — so results are bitwise identical no matter how many workers
//! participate; threads only change *which* worker computes a chunk.
//!
//! The two layers cooperate through a per-thread *budget*
//! ([`set_intra_budget`]): when the engine has many independent heavy ops
//! in flight it caps how many intra-op workers each op may recruit,
//! avoiding oversubscription (see `engine::threaded`).

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

struct Shared {
    rx: Mutex<mpsc::Receiver<Msg>>,
    /// Jobs submitted but not yet finished; guarded by `idle` for wait().
    inflight: AtomicUsize,
    idle: (Mutex<()>, Condvar),
}

/// Fixed-size thread pool with a `wait_idle` barrier.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size` worker threads (clamped to >= 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let shared = Arc::new(Shared {
            rx: Mutex::new(rx),
            inflight: AtomicUsize::new(0),
            idle: (Mutex::new(()), Condvar::new()),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("mixnet-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cvar) = &self.shared.idle;
        let mut guard = lock.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = cvar.wait(guard).unwrap();
        }
        drop(guard);
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let msg = {
            let rx = shared.rx.lock().unwrap();
            rx.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                job();
                let prev = shared.inflight.fetch_sub(1, Ordering::SeqCst);
                if prev == 1 {
                    let (lock, cvar) = &shared.idle;
                    let _g = lock.lock().unwrap();
                    cvar.notify_all();
                }
            }
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // The pool can be dropped *from one of its own workers* (the last
        // op closure may own the last Arc to the engine); joining oneself
        // would deadlock (EDEADLK), so that worker is detached instead —
        // it exits on the Shutdown message it already has queued.
        let me = std::thread::current().id();
        for w in self.workers.drain(..) {
            if w.thread().id() != me {
                let _ = w.join();
            }
        }
    }
}

// ---------------------------------------------------------------------
// Intra-op parallelism
// ---------------------------------------------------------------------

/// One broadcast job: workers (and the submitter) race on `next` to claim
/// chunk indices until the range is exhausted.
struct JobCore {
    /// Borrowed closure, lifetime-erased.  Sound because
    /// [`IntraPool::run`] does not return until `pending == 0`, and a
    /// worker only dereferences `f` for a chunk index it won from `next`
    /// (`next < nchunks`), which also implies `pending > 0` at that time.
    /// Chunk bodies are run under `catch_unwind` so a panicking chunk
    /// still decrements `pending` — the completion wait (and therefore
    /// the borrow's validity) survives panics.
    f: &'static (dyn Fn(usize) + Sync),
    /// Next chunk index to claim.
    next: AtomicUsize,
    /// Total chunks.
    nchunks: usize,
    /// Chunks not yet completed; the run is over when this hits 0.
    pending: AtomicUsize,
    /// Workers admitted so far; capped to the submitter's budget.
    entered: AtomicUsize,
    /// Max participants (budget), including the submitting thread.
    cap: usize,
    /// First panic payload from any chunk; re-raised on the submitting
    /// thread after every chunk has completed.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct IntraShared {
    /// Current job broadcast, tagged with a generation counter so a
    /// worker never re-enters a job it already drained.
    slot: Mutex<(u64, Option<Arc<JobCore>>)>,
    work_cv: Condvar,
    done_cv: Condvar,
    shutdown: AtomicBool,
}

/// A process-wide pool for *intra-op* parallelism (`parallel_for`).
///
/// One job runs at a time; the submitting thread always participates, so
/// a 1-thread pool degenerates to plain serial execution with no
/// cross-thread traffic.  Nested `run` calls from inside a chunk execute
/// serially inline (no deadlock, no oversubscription).
pub struct IntraPool {
    shared: Arc<IntraShared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

thread_local! {
    /// Set while a thread executes chunks of a job; makes nested
    /// `parallel_for` serial.
    static IN_PARALLEL_REGION: Cell<bool> = const { Cell::new(false) };
    /// Per-thread cap on intra-op workers, set by the engine before
    /// running an op (usize::MAX = uncapped).
    static INTRA_BUDGET: Cell<usize> = const { Cell::new(usize::MAX) };
}

impl IntraPool {
    /// Create a pool that computes with `threads` total threads (the
    /// submitter plus `threads - 1` workers).  Clamped to >= 1.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(IntraShared {
            slot: Mutex::new((0, None)),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("mixnet-intra-{i}"))
                    .spawn(move || intra_worker_loop(shared))
                    .expect("spawn intra worker")
            })
            .collect();
        IntraPool { shared, workers, threads }
    }

    /// Total compute threads (submitter + workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(chunk)` for every `chunk in 0..nchunks`, fanning out over
    /// at most `cap` threads (including the caller).  Blocks until every
    /// chunk has completed.  Chunks are claimed dynamically but the chunk
    /// *contents* are fixed by the caller, so any data written to
    /// disjoint per-chunk regions is independent of thread count.
    pub fn run(&self, nchunks: usize, cap: usize, f: &(dyn Fn(usize) + Sync)) {
        let cap = cap.min(self.threads).max(1);
        let serial = nchunks <= 1
            || cap == 1
            || self.workers.is_empty()
            || IN_PARALLEL_REGION.with(|c| c.get());
        if serial {
            for i in 0..nchunks {
                f(i);
            }
            return;
        }
        // SAFETY: see `JobCore::f` — the borrow outlives every
        // dereference because `run` blocks until `pending == 0`.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let job = Arc::new(JobCore {
            f: f_static,
            next: AtomicUsize::new(0),
            nchunks,
            pending: AtomicUsize::new(nchunks),
            entered: AtomicUsize::new(1), // the submitter
            cap,
            panic: Mutex::new(None),
        });
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.0 += 1;
            slot.1 = Some(Arc::clone(&job));
            self.shared.work_cv.notify_all();
        }
        // The submitter works too, flagged so nested calls stay serial.
        IN_PARALLEL_REGION.with(|c| c.set(true));
        Self::drain(&self.shared, &job);
        IN_PARALLEL_REGION.with(|c| c.set(false));
        // Wait for chunks still running on workers.
        {
            let mut slot = self.shared.slot.lock().unwrap();
            while job.pending.load(Ordering::Acquire) != 0 {
                slot = self.shared.done_cv.wait(slot).unwrap();
            }
            // Clear the broadcast so idle workers stop seeing the drained
            // job — but only if a concurrent `run` has not already
            // replaced it with its own (each submitter always completes
            // its own chunks, so overlapping runs stay correct; they just
            // share workers less efficiently).
            if slot.1.as_ref().is_some_and(|j| Arc::ptr_eq(j, &job)) {
                slot.1 = None;
            }
        }
        // Re-raise a chunk panic on the submitting thread, now that the
        // borrow of `f` is provably dead.  The engine layer catches it
        // like any other op panic.
        if let Some(payload) = job.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Claim and execute chunks until the range is exhausted.  A chunk
    /// panic is caught so `pending` always reaches 0 (no deadlocked
    /// submitter, no dangling `f` borrow); the first payload is stashed
    /// for the submitter to re-raise.
    fn drain(shared: &IntraShared, job: &JobCore) {
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.nchunks {
                return;
            }
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (job.f)(i)));
            if let Err(payload) = result {
                let mut slot = job.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                let _g = shared.slot.lock().unwrap();
                shared.done_cv.notify_all();
            }
        }
    }
}

fn intra_worker_loop(shared: Arc<IntraShared>) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if slot.0 != seen {
                    seen = slot.0;
                    if let Some(j) = slot.1.as_ref() {
                        break Arc::clone(j);
                    }
                    continue; // stale generation with no job
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        // Admission control: respect the submitter's thread budget.
        if job.entered.fetch_add(1, Ordering::Relaxed) >= job.cap {
            continue;
        }
        IN_PARALLEL_REGION.with(|c| c.set(true));
        IntraPool::drain(&shared, &job);
        IN_PARALLEL_REGION.with(|c| c.set(false));
    }
}

impl Drop for IntraPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _g = self.shared.slot.lock().unwrap();
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The process-wide intra-op pool.  Thread count comes from
/// `PALLAS_INTRA_THREADS` (default: all hardware threads).
pub fn intra_pool() -> &'static IntraPool {
    static POOL: OnceLock<IntraPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let threads = std::env::var("PALLAS_INTRA_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        IntraPool::new(threads)
    })
}

/// Cap the number of intra-op workers ops on *this thread* may recruit
/// (set by the engine before invoking an op body; `usize::MAX` = no cap).
/// Returns the previous value so callers can restore it.
pub fn set_intra_budget(cap: usize) -> usize {
    INTRA_BUDGET.with(|c| c.replace(cap.max(1)))
}

/// Effective intra-op parallelism available to the current thread.
pub fn intra_budget() -> usize {
    INTRA_BUDGET.with(|c| c.get()).min(intra_pool().threads())
}

/// Run `f` with the intra-op budget temporarily set to `cap` (tests and
/// benches: pin the worker count regardless of pool size).  The previous
/// budget is restored even if `f` panics, so a failing assertion cannot
/// leak a pinned budget onto a reused test-harness thread.
pub fn with_intra_budget<R>(cap: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            INTRA_BUDGET.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(set_intra_budget(cap));
    f()
}

/// Estimated FLOPs (or element-ops) below which a kernel is not worth
/// fanning out: at ~1 GFLOP/s-per-core serial floor this is ~0.5 ms of
/// work, comfortably above the pool's wake/communication latency.
pub const INTRA_MIN_COST: f64 = 5e5;

/// Chunked parallel iteration over `0..n`: calls `f(lo..hi)` for
/// consecutive ranges of at most `grain` items.
///
/// The partition depends only on `n` and `grain`, so kernels that write
/// disjoint per-chunk output regions produce bitwise-identical results
/// for every thread count — including fully serial execution, which uses
/// the *same* chunk sequence.
pub fn parallel_for(n: usize, grain: usize, f: impl Fn(Range<usize>) + Sync) {
    let grain = grain.max(1);
    let nchunks = n.div_ceil(grain);
    if nchunks == 0 {
        return;
    }
    let chunk = |i: usize| {
        let lo = i * grain;
        let hi = (lo + grain).min(n);
        f(lo..hi);
    };
    let budget = intra_budget();
    if nchunks == 1 || budget <= 1 {
        for i in 0..nchunks {
            chunk(i);
        }
        return;
    }
    intra_pool().run(nchunks, budget, &chunk);
}

/// [`parallel_for`] gated by an estimated cost: below [`INTRA_MIN_COST`]
/// the loop runs serially (same chunk partition, so same results).
pub fn parallel_for_cost(n: usize, grain: usize, cost: f64, f: impl Fn(Range<usize>) + Sync) {
    let grain = grain.max(1);
    if !(cost >= INTRA_MIN_COST) {
        // NaN (unknown) and cheap both stay serial.
        let mut lo = 0;
        while lo < n {
            let hi = (lo + grain).min(n);
            f(lo..hi);
            lo = hi;
        }
        return;
    }
    parallel_for(n, grain, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn jobs_can_submit_more_jobs() {
        let pool = Arc::new(ThreadPool::new(2));
        let counter = Arc::new(AtomicU64::new(0));
        // A job is not allowed to submit into the same pool it runs on
        // (the engine never does this either: completion callbacks run on
        // the scheduler side).  Submit from a separate thread instead.
        let (tx, rx) = mpsc::channel();
        {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        rx.recv().unwrap();
        {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    // ---- intra-op pool -----------------------------------------------

    #[test]
    fn intra_run_covers_every_chunk_exactly_once() {
        let pool = IntraPool::new(4);
        let hits: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
        pool.run(hits.len(), 4, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i}");
        }
    }

    #[test]
    fn intra_run_reusable_across_jobs() {
        let pool = IntraPool::new(3);
        for round in 1..=5u64 {
            let sum = AtomicU64::new(0);
            pool.run(16, 3, &|i| {
                sum.fetch_add(round * i as u64, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), round * (0..16).sum::<u64>());
        }
    }

    #[test]
    fn intra_single_thread_pool_is_serial_inline() {
        let pool = IntraPool::new(1);
        let order = Mutex::new(Vec::new());
        pool.run(8, 8, &|i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn nested_run_executes_serially_without_deadlock() {
        let pool = Arc::new(IntraPool::new(4));
        let total = AtomicU64::new(0);
        let p = Arc::clone(&pool);
        pool.run(4, 4, &|_| {
            // nested: must run inline on this worker, not hang
            p.run(4, 4, &|j| {
                total.fetch_add(1 + j as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * (1 + 2 + 3 + 4));
    }

    #[test]
    fn panicking_chunk_neither_deadlocks_nor_leaks() {
        let pool = IntraPool::new(4);
        let done = AtomicU64::new(0);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, 4, &|i| {
                if i == 3 {
                    panic!("intentional chunk panic");
                }
                done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(res.is_err(), "chunk panic must re-raise on the submitter");
        assert_eq!(done.load(Ordering::Relaxed), 7, "other chunks still run");
        // The pool must remain fully usable afterwards.
        let sum = AtomicU64::new(0);
        pool.run(4, 4, &|i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn parallel_for_partition_is_thread_count_independent() {
        // Collect the chunk ranges under budget 1 and budget 4: the
        // partitions must be identical (order may differ under 4).
        let ranges = |budget: usize| {
            let out = Mutex::new(Vec::new());
            with_intra_budget(budget, || {
                parallel_for(103, 10, |r| out.lock().unwrap().push((r.start, r.end)));
            });
            let mut v = out.into_inner().unwrap();
            v.sort_unstable();
            v
        };
        assert_eq!(ranges(1), ranges(4));
    }

    #[test]
    fn parallel_for_cost_gates_cheap_work_serial() {
        // Cheap: runs on the calling thread in order.
        let order = Mutex::new(Vec::new());
        parallel_for_cost(10, 2, 1.0, |r| order.lock().unwrap().push(r.start));
        assert_eq!(*order.lock().unwrap(), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn with_intra_budget_restores_previous() {
        let before = intra_budget();
        with_intra_budget(1, || {
            assert_eq!(intra_budget(), 1);
        });
        assert_eq!(intra_budget(), before);
    }

    #[test]
    fn concurrent_runs_from_two_threads_both_complete() {
        let pool = Arc::new(IntraPool::new(4));
        let a = Arc::clone(&pool);
        let t = std::thread::spawn(move || {
            let sum = AtomicU64::new(0);
            for _ in 0..50 {
                a.run(8, 4, &|i| {
                    sum.fetch_add(i as u64, Ordering::Relaxed);
                });
            }
            sum.load(Ordering::Relaxed)
        });
        let sum = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run(8, 4, &|i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 50 * 28);
        assert_eq!(t.join().unwrap(), 50 * 28);
    }
}

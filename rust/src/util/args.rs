//! A tiny flag parser for the CLI and benches (the crate is deliberately
//! dependency-light, so no clap).
//!
//! Grammar: `--key value` and `--flag` (boolean), with positionals kept
//! in order.  Unknown keys are collected so callers can reject them.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// `--key value` pairs.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (exclude argv[0]).
    ///
    /// `value_keys` lists the options that consume a value; anything else
    /// starting with `--` is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I, value_keys: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if value_keys.contains(&key) {
                    let val = it
                        .next()
                        .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?;
                    args.options.insert(key.to_string(), val);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Get an option parsed as `T`, or `default`.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: cannot parse '{v}'"))),
        }
    }

    /// Get a string option.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Whether a boolean flag was given.
    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = Args::parse(
            v(&["train", "--model", "mlp", "--verbose", "--epochs", "3", "extra"]),
            &["model", "epochs"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get_str("model", "x"), "mlp");
        assert_eq!(a.get::<usize>("epochs", 0).unwrap(), 3);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(v(&["--model"]), &["model"]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(v(&[]), &[]).unwrap();
        assert_eq!(a.get::<usize>("epochs", 7).unwrap(), 7);
        assert_eq!(a.get_str("model", "mlp"), "mlp");
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(v(&["--epochs", "many"]), &["epochs"]).unwrap();
        assert!(a.get::<usize>("epochs", 0).is_err());
    }
}

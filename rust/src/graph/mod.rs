//! The computation graph IR (paper §3.1).
//!
//! A bound symbolic expression is represented as a [`Graph`]: a vector of
//! [`Node`]s in topological order, each applying an [`Op`] to input
//! [`Entry`]s (node, output-index pairs).  The graph is the unit on which
//! the paper's optimizations operate:
//!
//! * [`autodiff`] appends the backward pass ("backward" in §2.1),
//! * [`optimize`] prunes unreached nodes and fuses elementwise chains
//!   ("graph optimization" in §3.1),
//! * [`memory`] plans storage with the *inplace* and *co-share* heuristics
//!   ("memory allocation" in §3.1, Figure 7).

pub mod autodiff;
pub mod memory;
pub mod optimize;
pub mod recompute;
pub mod viz;

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::ndarray::kernels::{ActKind, EwBinary, PoolKind};

/// Node index within a [`Graph`].
pub type NodeId = usize;

/// A value in the graph: output `out` of node `node`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Entry {
    /// Producing node.
    pub node: NodeId,
    /// Output index of the producing node.
    pub out: usize,
}

impl Entry {
    /// First output of `node`.
    pub fn new(node: NodeId) -> Self {
        Entry { node, out: 0 }
    }
}

/// One step of a fused elementwise chain (see [`Op::FusedElemwise`] and
/// the epilogue fields of [`Op::FullyConnected`] / [`Op::Convolution`]).
#[derive(Debug, Clone, PartialEq)]
pub enum FusedStep {
    /// Apply an activation.
    Act(ActKind),
    /// Add a constant.
    AddScalar(f32),
    /// Multiply by a constant.
    MulScalar(f32),
    /// Combine with the next extra input elementwise.
    Binary(EwBinary),
}

impl FusedStep {
    /// Short lowercase label for graph dumps (`relu`, `add0.5`, ...).
    pub fn label(&self) -> String {
        match self {
            FusedStep::Act(ActKind::Relu) => "relu".into(),
            FusedStep::Act(ActKind::Tanh) => "tanh".into(),
            FusedStep::Act(ActKind::Sigmoid) => "sigmoid".into(),
            FusedStep::AddScalar(s) => format!("add{s}"),
            FusedStep::MulScalar(s) => format!("mul{s}"),
            FusedStep::Binary(EwBinary::Add) => "add".into(),
            FusedStep::Binary(EwBinary::Sub) => "sub".into(),
            FusedStep::Binary(EwBinary::Mul) => "mul".into(),
            FusedStep::Binary(EwBinary::Div) => "div".into(),
        }
    }
}

/// Graph operators.
///
/// Forward "layer" ops mirror the paper's coarse-grained operators
/// (§3.1: *"manually implemented well-optimized big operations, such as a
/// layer in neural network"*); `*Backward` ops are emitted by
/// [`autodiff`].  Input/output signatures are documented per variant.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Free variable (input data, label, or parameter). No inputs, 1 out.
    Variable,
    /// `[b,in] x [hidden,in] x [hidden] -> [b,hidden]` (x, weight, bias).
    ///
    /// When `epilogue` is non-empty (set only by
    /// [`optimize::fuse_epilogue`]) the steps run on each output element
    /// right after its GEMM accumulation + bias, while the tile is still
    /// cache-hot; every `Binary` step consumes one extra input (appended
    /// after x, w, b) of the output shape.
    FullyConnected {
        /// Output width.
        num_hidden: usize,
        /// Fused post-GEMM elementwise chain (empty = plain FC).
        epilogue: Vec<FusedStep>,
    },
    /// NCHW convolution: `(x[n,c,h,w], w[f,c,kh,kw], b[f]) -> y[n,f,oh,ow]`.
    ///
    /// `epilogue` as on [`Op::FullyConnected`]: a fused per-element chain
    /// applied per image right after im2col+GEMM+bias.
    Convolution {
        /// Number of output filters.
        num_filter: usize,
        /// Square kernel size.
        kernel: usize,
        /// Stride.
        stride: usize,
        /// Zero padding.
        pad: usize,
        /// Fused post-conv elementwise chain (empty = plain conv).
        epilogue: Vec<FusedStep>,
    },
    /// Elementwise activation: `x -> y`.
    Activation {
        /// Which nonlinearity.
        kind: ActKind,
    },
    /// Square pooling: `x[n,c,h,w] -> (y[n,c,oh,ow], argmax[n,c,oh,ow])`.
    Pooling {
        /// Max or average.
        kind: PoolKind,
        /// Window size.
        kernel: usize,
        /// Stride.
        stride: usize,
        /// Zero padding.
        pad: usize,
    },
    /// Batch normalization over channel axis:
    /// `(x, gamma[c], beta[c]) -> (y, save_mean[c], save_invstd[c])`.
    BatchNorm {
        /// Numerical-stability epsilon.
        eps: f32,
    },
    /// Collapse trailing dims: `[n, ...] -> [n, prod(...)]`.
    Flatten,
    /// Elementwise binary: `(a, b) -> y`.
    Elemwise {
        /// Which binary op.
        op: EwBinary,
    },
    /// `x + s`.
    AddScalar {
        /// Constant.
        s: f32,
    },
    /// `x * s`.
    MulScalar {
        /// Constant.
        s: f32,
    },
    /// Sum of `n` same-shaped inputs (gradient accumulation).
    AddN,
    /// Identity / copy.
    Identity,
    /// Channel-axis concat of NCHW inputs (the Inception merge).
    Concat,
    /// Dropout: `x -> (y, mask)`; `p` is drop probability.
    Dropout {
        /// Drop probability.
        p: f32,
        /// Seed for mask generation.
        seed: u64,
    },
    /// Softmax over the last axis plus cross-entropy head:
    /// `(x[b,n], label[b]) -> prob[b,n]`.
    SoftmaxOutput,
    /// Optimizer-fused elementwise chain over the first input, consuming
    /// one extra input per `Binary` step.
    FusedElemwise {
        /// Steps applied in order.
        steps: Vec<FusedStep>,
    },

    // ----- backward ops (emitted by autodiff) -----
    /// `(dy, x, w) -> (dx, dw, db)`.
    FullyConnectedBackward,
    /// `(dy, x, w) -> (dx, dw, db)`.
    ConvolutionBackward {
        /// Forward kernel size.
        kernel: usize,
        /// Forward stride.
        stride: usize,
        /// Forward padding.
        pad: usize,
    },
    /// `(dy, y) -> dx` (computed from the output, freeing the input).
    ActivationBackward {
        /// Which nonlinearity.
        kind: ActKind,
    },
    /// `(dy, argmax, x) -> dx`.
    PoolingBackward {
        /// Max or average.
        kind: PoolKind,
        /// Window size.
        kernel: usize,
        /// Stride.
        stride: usize,
        /// Zero padding.
        pad: usize,
    },
    /// `(dy, x, gamma, save_mean, save_invstd) -> (dx, dgamma, dbeta)`.
    BatchNormBackward,
    /// `(dy, x) -> dx` (reshape of dy to x's shape).
    FlattenBackward,
    /// `(prob, label) -> dx` — combined softmax+CE gradient.
    SoftmaxOutputBackward,
    /// `(dy, x_1..x_k) -> (dx_1..dx_k)` — split dy along channels.
    ConcatBackward,
    /// `(dy, mask) -> dx`.
    DropoutBackward,
}

impl Op {
    /// Number of outputs this op produces (`k` = input count for
    /// variadic backward splits).
    pub fn num_outputs(&self, num_inputs: usize) -> usize {
        match self {
            Op::Pooling { .. } | Op::Dropout { .. } => 2,
            Op::BatchNorm { .. } => 3,
            Op::FullyConnectedBackward
            | Op::ConvolutionBackward { .. }
            | Op::BatchNormBackward => 3,
            Op::ConcatBackward => num_inputs.saturating_sub(1),
            _ => 1,
        }
    }

    /// Whether this is a `Variable` placeholder.
    pub fn is_variable(&self) -> bool {
        matches!(self, Op::Variable)
    }

    /// Inplace-capable (input_idx, output_idx) identity pairs: the output
    /// may reuse the input's storage (paper's *inplace* heuristic).
    pub fn inplace_pairs(&self) -> &'static [(usize, usize)] {
        match self {
            Op::Activation { .. }
            | Op::AddScalar { .. }
            | Op::MulScalar { .. }
            | Op::Identity
            | Op::Flatten
            | Op::FusedElemwise { .. } => &[(0, 0)],
            Op::Elemwise { .. } | Op::AddN => &[(0, 0), (1, 0)],
            Op::ActivationBackward { .. } | Op::FlattenBackward | Op::DropoutBackward => &[(0, 0)],
            _ => &[],
        }
    }

    /// Short name for visualization / profiling.
    pub fn type_name(&self) -> &'static str {
        match self {
            Op::Variable => "Variable",
            Op::FullyConnected { epilogue, .. } if !epilogue.is_empty() => "FullyConnected+ep",
            Op::Convolution { epilogue, .. } if !epilogue.is_empty() => "Convolution+ep",
            Op::FullyConnected { .. } => "FullyConnected",
            Op::Convolution { .. } => "Convolution",
            Op::Activation { .. } => "Activation",
            Op::Pooling { .. } => "Pooling",
            Op::BatchNorm { .. } => "BatchNorm",
            Op::Flatten => "Flatten",
            Op::Elemwise { .. } => "Elemwise",
            Op::AddScalar { .. } => "AddScalar",
            Op::MulScalar { .. } => "MulScalar",
            Op::AddN => "AddN",
            Op::Identity => "Identity",
            Op::Concat => "Concat",
            Op::Dropout { .. } => "Dropout",
            Op::SoftmaxOutput => "SoftmaxOutput",
            Op::FusedElemwise { .. } => "FusedElemwise",
            Op::FullyConnectedBackward => "FullyConnectedBackward",
            Op::ConvolutionBackward { .. } => "ConvolutionBackward",
            Op::ActivationBackward { .. } => "ActivationBackward",
            Op::PoolingBackward { .. } => "PoolingBackward",
            Op::BatchNormBackward => "BatchNormBackward",
            Op::FlattenBackward => "FlattenBackward",
            Op::SoftmaxOutputBackward => "SoftmaxOutputBackward",
            Op::ConcatBackward => "ConcatBackward",
            Op::DropoutBackward => "DropoutBackward",
        }
    }

    /// The fused epilogue chain of an epilogue-capable op (empty slice
    /// for everything else).
    pub fn epilogue(&self) -> &[FusedStep] {
        match self {
            Op::FullyConnected { epilogue, .. } | Op::Convolution { epilogue, .. } => epilogue,
            _ => &[],
        }
    }

    /// Human-readable label: [`Op::type_name`] with the fused epilogue
    /// chain spelled out (e.g. `FullyConnected+relu`), so dumped graphs
    /// show what the compiler actually ran.
    pub fn label(&self) -> String {
        let ep = self.epilogue();
        if ep.is_empty() {
            return self.type_name().to_string();
        }
        let base = match self {
            Op::Convolution { .. } => "Convolution",
            _ => "FullyConnected",
        };
        let mut s = base.to_string();
        for st in ep {
            s.push('+');
            s.push_str(&st.label());
        }
        s
    }
}

/// One graph node: an op applied to input entries.
#[derive(Debug, Clone)]
pub struct Node {
    /// The operator.
    pub op: Op,
    /// Unique-ish human-readable name (binding key for variables).
    pub name: String,
    /// Input values.
    pub inputs: Vec<Entry>,
    /// Extra ordering constraints (used by the co-share memory planner).
    pub control_deps: Vec<NodeId>,
}

/// A computation graph in topological order.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Nodes; every input entry refers to a lower index.
    pub nodes: Vec<Node>,
    /// Requested outputs (forward heads).
    pub outputs: Vec<Entry>,
    /// Nodes `>= num_forward` belong to the backward pass (0 = all
    /// forward).  Set by [`autodiff::build_backward`].
    pub num_forward: usize,
}

impl Graph {
    /// Create an empty graph.
    pub fn new() -> Self {
        Graph::default()
    }

    /// Append a node, returning its id. Inputs must already exist.
    pub fn add_node(&mut self, op: Op, name: impl Into<String>, inputs: Vec<Entry>) -> NodeId {
        for e in &inputs {
            debug_assert!(e.node < self.nodes.len(), "forward reference");
        }
        self.nodes.push(Node { op, name: name.into(), inputs, control_deps: vec![] });
        self.nodes.len() - 1
    }

    /// Add a `Variable` node.
    pub fn add_variable(&mut self, name: impl Into<String>) -> NodeId {
        self.add_node(Op::Variable, name, vec![])
    }

    /// Ids of all variable nodes, in order.
    pub fn variables(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.op.is_variable())
            .map(|(i, _)| i)
            .collect()
    }

    /// Find a variable node by name.
    pub fn find_variable(&self, name: &str) -> Option<NodeId> {
        self.nodes.iter().position(|n| n.op.is_variable() && n.name == name)
    }

    /// Number of outputs of node `id`.
    pub fn num_outputs_of(&self, id: NodeId) -> usize {
        self.nodes[id].op.num_outputs(self.nodes[id].inputs.len())
    }

    /// Per-entry consumer counts (+1 for each appearance in `outputs` and
    /// in `extra_roots`).
    pub fn entry_refcounts(&self, extra_roots: &[Entry]) -> HashMap<Entry, usize> {
        let mut rc: HashMap<Entry, usize> = HashMap::new();
        for n in &self.nodes {
            for e in &n.inputs {
                *rc.entry(*e).or_insert(0) += 1;
            }
        }
        for e in self.outputs.iter().chain(extra_roots) {
            *rc.entry(*e).or_insert(0) += 1;
        }
        rc
    }

    /// Validate topological ordering (inputs precede consumers).
    pub fn validate(&self) -> Result<()> {
        for (i, n) in self.nodes.iter().enumerate() {
            for e in &n.inputs {
                if e.node >= i {
                    return Err(Error::graph(format!(
                        "node {i} ({}) consumes entry from node {} out of order",
                        n.name, e.node
                    )));
                }
                let avail = self.num_outputs_of(e.node);
                if e.out >= avail {
                    return Err(Error::graph(format!(
                        "node {i} ({}) reads output {} of node {} which has {avail}",
                        n.name, e.out, e.node
                    )));
                }
            }
            for &c in &n.control_deps {
                if c >= i {
                    return Err(Error::graph(format!(
                        "node {i} ({}) has forward control dep on {c}",
                        n.name
                    )));
                }
            }
        }
        for e in &self.outputs {
            if e.node >= self.nodes.len() {
                return Err(Error::graph("output references missing node"));
            }
        }
        Ok(())
    }
}

/// Inferred shapes: `shapes[node][out]` is the dims of that entry.
pub type ShapeMap = Vec<Vec<Vec<usize>>>;

/// Validate that an epilogue's `Binary` steps line up with a fused
/// node's extra inputs: exactly one extra per `Binary` step, each of the
/// node's output shape.
fn check_epilogue_extras(
    epilogue: &[FusedStep],
    extras: &[&Vec<usize>],
    out: &[usize],
) -> std::result::Result<(), String> {
    let binaries = epilogue.iter().filter(|s| matches!(s, FusedStep::Binary(_))).count();
    if extras.len() != binaries {
        return Err(format!(
            "epilogue has {binaries} binary step(s) but {} extra input(s)",
            extras.len()
        ));
    }
    for (i, s) in extras.iter().enumerate() {
        if s.as_slice() != out {
            return Err(format!("epilogue operand {i} shape {s:?} != output {out:?}"));
        }
    }
    Ok(())
}

/// Infer every entry's shape from the shapes of `Variable` nodes.
///
/// `var_shapes` maps variable *names* to shapes.  Fails if a variable is
/// missing or an op's constraints are violated.
pub fn infer_shapes(graph: &Graph, var_shapes: &HashMap<String, Vec<usize>>) -> Result<ShapeMap> {
    use crate::ndarray::kernels::conv_out;
    let mut shapes: ShapeMap = Vec::with_capacity(graph.nodes.len());
    for (id, node) in graph.nodes.iter().enumerate() {
        let ins: Vec<&Vec<usize>> =
            node.inputs.iter().map(|e| &shapes[e.node][e.out]).collect();
        let err = |msg: String| Error::shape(format!("node {id} ({}): {msg}", node.name));
        let out: Vec<Vec<usize>> = match &node.op {
            Op::Variable => {
                let s = var_shapes
                    .get(&node.name)
                    .ok_or_else(|| err(format!("no shape bound for variable '{}'", node.name)))?;
                vec![s.clone()]
            }
            Op::FullyConnected { num_hidden, epilogue } => {
                if ins.len() < 3 {
                    return Err(err("FullyConnected needs (x, w, b)".into()));
                }
                let b = ins[0][0];
                let in_dim: usize = ins[0][1..].iter().product();
                if ins[1] != &vec![*num_hidden, in_dim] {
                    return Err(err(format!(
                        "weight shape {:?} != [{num_hidden}, {in_dim}]",
                        ins[1]
                    )));
                }
                if ins[2] != &vec![*num_hidden] {
                    return Err(err(format!("bias shape {:?} != [{num_hidden}]", ins[2])));
                }
                let out = vec![b, *num_hidden];
                check_epilogue_extras(epilogue, &ins[3..], &out).map_err(err)?;
                vec![out]
            }
            Op::Convolution { num_filter, kernel, stride, pad, epilogue } => {
                if ins.len() < 3 || ins[0].len() != 4 {
                    return Err(err("Convolution needs (x[n,c,h,w], w, b)".into()));
                }
                let (n, c, h, w) = (ins[0][0], ins[0][1], ins[0][2], ins[0][3]);
                if ins[1] != &vec![*num_filter, c, *kernel, *kernel] {
                    return Err(err(format!(
                        "weight shape {:?} != [{num_filter}, {c}, {kernel}, {kernel}]",
                        ins[1]
                    )));
                }
                let oh = conv_out(h, *kernel, *stride, *pad);
                let ow = conv_out(w, *kernel, *stride, *pad);
                let out = vec![n, *num_filter, oh, ow];
                check_epilogue_extras(epilogue, &ins[3..], &out).map_err(err)?;
                vec![out]
            }
            Op::Activation { .. } | Op::AddScalar { .. } | Op::MulScalar { .. } | Op::Identity => {
                vec![ins[0].clone()]
            }
            Op::Pooling { kernel, stride, pad, .. } => {
                if ins[0].len() != 4 {
                    return Err(err("Pooling needs NCHW".into()));
                }
                let (n, c, h, w) = (ins[0][0], ins[0][1], ins[0][2], ins[0][3]);
                let oh = conv_out(h, *kernel, *stride, *pad);
                let ow = conv_out(w, *kernel, *stride, *pad);
                let o = vec![n, c, oh, ow];
                vec![o.clone(), o]
            }
            Op::BatchNorm { .. } => {
                let c = if ins[0].len() >= 2 { ins[0][1] } else { ins[0][0] };
                if ins[1] != &vec![c] || ins[2] != &vec![c] {
                    return Err(err("BatchNorm gamma/beta must be [c]".into()));
                }
                vec![ins[0].clone(), vec![c], vec![c]]
            }
            Op::Flatten => {
                let n = ins[0][0];
                let rest: usize = ins[0][1..].iter().product();
                vec![vec![n, rest]]
            }
            Op::Elemwise { .. } => {
                if ins[0] != ins[1] {
                    return Err(err(format!("elemwise shape {:?} vs {:?}", ins[0], ins[1])));
                }
                vec![ins[0].clone()]
            }
            Op::AddN => {
                for s in &ins[1..] {
                    if *s != ins[0] {
                        return Err(err("AddN inputs must share shape".into()));
                    }
                }
                vec![ins[0].clone()]
            }
            Op::Concat => {
                let first = ins[0].clone();
                let mut ch = first[1];
                for s in &ins[1..] {
                    if s.len() != first.len()
                        || s[0] != first[0]
                        || s[2..] != first[2..]
                    {
                        return Err(err("Concat inputs differ off-channel".into()));
                    }
                    ch += s[1];
                }
                let mut o = first;
                o[1] = ch;
                vec![o]
            }
            Op::Dropout { .. } => vec![ins[0].clone(), ins[0].clone()],
            Op::SoftmaxOutput => {
                if ins.len() != 2 || ins[0].len() != 2 {
                    return Err(err("SoftmaxOutput needs (x[b,n], label[b])".into()));
                }
                if ins[1] != &vec![ins[0][0]] {
                    return Err(err(format!(
                        "label shape {:?} != [{}]",
                        ins[1], ins[0][0]
                    )));
                }
                vec![ins[0].clone()]
            }
            Op::FusedElemwise { steps } => {
                let mut extra = 1usize;
                for st in steps {
                    if let FusedStep::Binary(_) = st {
                        if ins.len() <= extra || ins[extra] != ins[0] {
                            return Err(err("fused binary input shape mismatch".into()));
                        }
                        extra += 1;
                    }
                }
                vec![ins[0].clone()]
            }
            Op::FullyConnectedBackward => {
                // (dy, x, w) -> (dx, dw, db)
                vec![ins[1].clone(), ins[2].clone(), vec![ins[0][1]]]
            }
            Op::ConvolutionBackward { .. } => {
                vec![ins[1].clone(), ins[2].clone(), vec![ins[0][1]]]
            }
            Op::ActivationBackward { .. } => vec![ins[0].clone()],
            Op::PoolingBackward { .. } => vec![ins[2].clone()],
            Op::BatchNormBackward => {
                let c = ins[2][0];
                vec![ins[1].clone(), vec![c], vec![c]]
            }
            Op::FlattenBackward => vec![ins[1].clone()],
            Op::SoftmaxOutputBackward => vec![ins[0].clone()],
            Op::ConcatBackward => ins[1..].iter().map(|s| (*s).clone()).collect(),
            Op::DropoutBackward => vec![ins[0].clone()],
        };
        shapes.push(out);
    }
    Ok(shapes)
}

/// Bytes of an entry given its dims (f32).
pub fn entry_bytes(dims: &[usize]) -> usize {
    dims.iter().product::<usize>() * std::mem::size_of::<f32>()
}

/// Per-node scratch workspace bytes (the engine's "temporal space"
/// resource; conv-backward im2col buffers).
///
/// Forward convolution no longer draws on planner workspace: its
/// image-parallel kernel uses per-thread scratch
/// (`ndarray::kernels::conv2d_forward`), so charging it here would
/// report — and lock — a buffer nobody touches.
pub fn workspace_bytes(graph: &Graph, shapes: &ShapeMap) -> Vec<usize> {
    graph
        .nodes
        .iter()
        .map(|node| match &node.op {
            Op::ConvolutionBackward { kernel, .. } => {
                let x = &shapes[node.inputs[1].node][node.inputs[1].out];
                let dy = &shapes[node.inputs[0].node][node.inputs[0].out];
                // per-image columns: [c*k*k, oh*ow]
                x[1] * kernel * kernel * dy[2] * dy[3] * 4
            }
            _ => 0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build the paper's Figure 2 MLP graph by hand.
    pub(crate) fn mlp_graph(batch: usize) -> (Graph, HashMap<String, Vec<usize>>) {
        let mut g = Graph::new();
        let data = g.add_variable("data");
        let w1 = g.add_variable("fc1_weight");
        let b1 = g.add_variable("fc1_bias");
        let fc1 = g.add_node(
            Op::FullyConnected { num_hidden: 64, epilogue: vec![] },
            "fc1",
            vec![Entry::new(data), Entry::new(w1), Entry::new(b1)],
        );
        let relu = g.add_node(Op::Activation { kind: ActKind::Relu }, "relu1", vec![Entry::new(fc1)]);
        let w2 = g.add_variable("fc2_weight");
        let b2 = g.add_variable("fc2_bias");
        let fc2 = g.add_node(
            Op::FullyConnected { num_hidden: 10, epilogue: vec![] },
            "fc2",
            vec![Entry::new(relu), Entry::new(w2), Entry::new(b2)],
        );
        let label = g.add_variable("label");
        let sm = g.add_node(Op::SoftmaxOutput, "softmax", vec![Entry::new(fc2), Entry::new(label)]);
        g.outputs = vec![Entry::new(sm)];
        g.num_forward = g.nodes.len();
        let mut shapes = HashMap::new();
        shapes.insert("data".into(), vec![batch, 784]);
        shapes.insert("fc1_weight".into(), vec![64, 784]);
        shapes.insert("fc1_bias".into(), vec![64]);
        shapes.insert("fc2_weight".into(), vec![10, 64]);
        shapes.insert("fc2_bias".into(), vec![10]);
        shapes.insert("label".into(), vec![batch]);
        (g, shapes)
    }

    #[test]
    fn mlp_shape_inference() {
        let (g, vs) = mlp_graph(32);
        g.validate().unwrap();
        let shapes = infer_shapes(&g, &vs).unwrap();
        let out = g.outputs[0];
        assert_eq!(shapes[out.node][out.out], vec![32, 10]);
    }

    #[test]
    fn missing_variable_shape_errors() {
        let (g, mut vs) = mlp_graph(32);
        vs.remove("fc2_weight");
        assert!(infer_shapes(&g, &vs).is_err());
    }

    #[test]
    fn bad_weight_shape_errors() {
        let (g, mut vs) = mlp_graph(32);
        vs.insert("fc1_weight".into(), vec![64, 100]);
        let e = infer_shapes(&g, &vs).unwrap_err();
        assert!(format!("{e}").contains("weight shape"));
    }

    #[test]
    fn conv_pool_shapes() {
        let mut g = Graph::new();
        let data = g.add_variable("data");
        let w = g.add_variable("w");
        let b = g.add_variable("b");
        let conv = g.add_node(
            Op::Convolution { num_filter: 8, kernel: 3, stride: 1, pad: 1, epilogue: vec![] },
            "conv",
            vec![Entry::new(data), Entry::new(w), Entry::new(b)],
        );
        let pool = g.add_node(
            Op::Pooling { kind: PoolKind::Max, kernel: 2, stride: 2, pad: 0 },
            "pool",
            vec![Entry::new(conv)],
        );
        g.outputs = vec![Entry::new(pool)];
        g.num_forward = g.nodes.len();
        let mut vs = HashMap::new();
        vs.insert("data".into(), vec![4, 3, 32, 32]);
        vs.insert("w".into(), vec![8, 3, 3, 3]);
        vs.insert("b".into(), vec![8]);
        let shapes = infer_shapes(&g, &vs).unwrap();
        assert_eq!(shapes[conv][0], vec![4, 8, 32, 32]);
        assert_eq!(shapes[pool][0], vec![4, 8, 16, 16]);
        // Forward conv uses per-thread scratch, not planner workspace
        // (see `workspace_bytes`); only ConvolutionBackward charges it.
        let ws = workspace_bytes(&g, &shapes);
        assert_eq!(ws[conv], 0);
        assert_eq!(ws[pool], 0);
    }

    #[test]
    fn conv_backward_charges_workspace() {
        let mut g = Graph::new();
        let dy = g.add_variable("dy");
        let x = g.add_variable("x");
        let w = g.add_variable("w");
        let bwd = g.add_node(
            Op::ConvolutionBackward { kernel: 3, stride: 1, pad: 1 },
            "conv_bwd",
            vec![Entry::new(dy), Entry::new(x), Entry::new(w)],
        );
        g.outputs = vec![Entry::new(bwd)];
        g.num_forward = g.nodes.len();
        let mut vs = HashMap::new();
        vs.insert("dy".into(), vec![4, 8, 32, 32]);
        vs.insert("x".into(), vec![4, 3, 32, 32]);
        vs.insert("w".into(), vec![8, 3, 3, 3]);
        let shapes = infer_shapes(&g, &vs).unwrap();
        let ws = workspace_bytes(&g, &shapes);
        // per-image im2col columns: [c*k*k, oh*ow] f32
        assert_eq!(ws[bwd], 3 * 3 * 3 * 32 * 32 * 4);
    }

    #[test]
    fn fused_epilogue_shapes_and_labels() {
        // FC with epilogue [relu, Binary(Add)]: extra operand must match
        // the output shape; the label spells the chain out.
        let mut g = Graph::new();
        let data = g.add_variable("data");
        let w = g.add_variable("w");
        let b = g.add_variable("b");
        let res = g.add_variable("res");
        let op = Op::FullyConnected {
            num_hidden: 4,
            epilogue: vec![FusedStep::Act(ActKind::Relu), FusedStep::Binary(EwBinary::Add)],
        };
        assert_eq!(op.type_name(), "FullyConnected+ep");
        assert_eq!(op.label(), "FullyConnected+relu+add");
        let fc = g.add_node(
            op,
            "fc_ep",
            vec![Entry::new(data), Entry::new(w), Entry::new(b), Entry::new(res)],
        );
        g.outputs = vec![Entry::new(fc)];
        g.num_forward = g.nodes.len();
        let mut vs = HashMap::new();
        vs.insert("data".into(), vec![2, 6]);
        vs.insert("w".into(), vec![4, 6]);
        vs.insert("b".into(), vec![4]);
        vs.insert("res".into(), vec![2, 4]);
        let shapes = infer_shapes(&g, &vs).unwrap();
        assert_eq!(shapes[fc][0], vec![2, 4]);
        // wrong operand shape is rejected
        vs.insert("res".into(), vec![4, 2]);
        assert!(infer_shapes(&g, &vs).is_err());
        // missing operand is rejected
        g.nodes[fc].inputs.pop();
        vs.insert("res".into(), vec![2, 4]);
        assert!(infer_shapes(&g, &vs).is_err());
    }

    #[test]
    fn concat_shapes() {
        let mut g = Graph::new();
        let a = g.add_variable("a");
        let b = g.add_variable("b");
        let cat = g.add_node(Op::Concat, "cat", vec![Entry::new(a), Entry::new(b)]);
        g.outputs = vec![Entry::new(cat)];
        g.num_forward = g.nodes.len();
        let mut vs = HashMap::new();
        vs.insert("a".into(), vec![2, 3, 8, 8]);
        vs.insert("b".into(), vec![2, 5, 8, 8]);
        let shapes = infer_shapes(&g, &vs).unwrap();
        assert_eq!(shapes[cat][0], vec![2, 8, 8, 8]);
    }

    #[test]
    fn validate_catches_forward_reference() {
        let mut g = Graph::new();
        let a = g.add_variable("a");
        g.nodes.push(Node {
            op: Op::Identity,
            name: "bad".into(),
            inputs: vec![Entry { node: 5, out: 0 }],
            control_deps: vec![],
        });
        let _ = a;
        assert!(g.validate().is_err());
    }

    #[test]
    fn refcounts_include_outputs() {
        let (g, _) = mlp_graph(8);
        let rc = g.entry_refcounts(&[]);
        let out = g.outputs[0];
        assert_eq!(rc[&out], 1);
        // data feeds fc1 only
        let data = g.find_variable("data").unwrap();
        assert_eq!(rc[&Entry::new(data)], 1);
    }
}

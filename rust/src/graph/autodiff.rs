//! Symbolic auto-differentiation ("backward" in paper §2.1).
//!
//! [`build_backward`] appends gradient nodes to a forward graph, producing
//! the combined forward+backward graph of Figure 4.  Gradients flow in
//! reverse topological order; fan-out is handled by summing partials with
//! an `AddN` node; "big op" gradients are dedicated `*Backward` operators
//! so the executor can dispatch them to the optimized kernels.

use std::collections::HashMap;

use super::{Entry, Graph, NodeId, Op};
use crate::error::{Error, Result};
use crate::ndarray::kernels::EwBinary;

/// Result of differentiating a graph.
#[derive(Debug, Clone)]
pub struct GradInfo {
    /// Gradient entry for each requested variable, keyed by node id.
    pub var_grads: HashMap<NodeId, Entry>,
}

/// Append the backward pass for `graph` (mutating it) and return the
/// gradient entries for `wrt` (variable node ids).
///
/// The loss head must be a `SoftmaxOutput` output (its gradient is the
/// fused `prob - onehot` of `SoftmaxOutputBackward`); additional heads are
/// treated as non-differentiated outputs.
pub fn build_backward(graph: &mut Graph, wrt: &[NodeId]) -> Result<GradInfo> {
    graph.num_forward = graph.nodes.len();
    let num_forward = graph.num_forward;

    // Partial gradients accumulated per forward entry.
    let mut partials: HashMap<Entry, Vec<Entry>> = HashMap::new();

    // Seed: every SoftmaxOutput head contributes its fused backward.
    let heads: Vec<Entry> = graph.outputs.clone();
    for head in &heads {
        let node = &graph.nodes[head.node];
        if let Op::SoftmaxOutput = node.op {
            let prob = *head;
            let label = node.inputs[1];
            let x = node.inputs[0];
            let name = format!("{}_backward", node.name);
            let bid = graph.add_node(Op::SoftmaxOutputBackward, name, vec![prob, label]);
            partials.entry(x).or_default().push(Entry::new(bid));
        }
    }
    if partials.is_empty() {
        return Err(Error::graph(
            "build_backward: no SoftmaxOutput head found to seed gradients",
        ));
    }

    // Sum partials into a single gradient entry.
    fn reduce(graph: &mut Graph, entry: Entry, parts: Vec<Entry>) -> Entry {
        if parts.len() == 1 {
            parts[0]
        } else {
            let name = format!("sum_grad_{}_{}", entry.node, entry.out);
            Entry::new(graph.add_node(Op::AddN, name, parts))
        }
    }

    // Walk forward nodes in reverse; each node whose output grad is known
    // emits input grads.
    for nid in (0..num_forward).rev() {
        let op = graph.nodes[nid].op.clone();
        if op.is_variable() {
            continue;
        }
        // Collect gradients of this node's outputs (if any are needed).
        let nout = graph.num_outputs_of(nid);
        let mut out_grads: Vec<Option<Entry>> = Vec::with_capacity(nout);
        for out in 0..nout {
            let e = Entry { node: nid, out };
            out_grads.push(match partials.remove(&e) {
                Some(parts) => Some(reduce(graph, e, parts)),
                None => None,
            });
        }
        if out_grads.iter().all(|g| g.is_none()) {
            continue;
        }
        let inputs = graph.nodes[nid].inputs.clone();
        let name = graph.nodes[nid].name.clone();
        let dy = out_grads[0];

        match op {
            Op::SoftmaxOutput => {
                // Seeded above; nothing else flows through (label has no grad).
            }
            Op::FullyConnected { .. } => {
                let dy = dy.expect("fc grad");
                let bid = graph.add_node(
                    Op::FullyConnectedBackward,
                    format!("{name}_backward"),
                    vec![dy, inputs[0], inputs[1]],
                );
                for (i, &inp) in inputs.iter().enumerate().take(3) {
                    partials.entry(inp).or_default().push(Entry { node: bid, out: i });
                }
            }
            Op::Convolution { kernel, stride, pad, .. } => {
                let dy = dy.expect("conv grad");
                let bid = graph.add_node(
                    Op::ConvolutionBackward { kernel, stride, pad },
                    format!("{name}_backward"),
                    vec![dy, inputs[0], inputs[1]],
                );
                for (i, &inp) in inputs.iter().enumerate().take(3) {
                    partials.entry(inp).or_default().push(Entry { node: bid, out: i });
                }
            }
            Op::Activation { kind } => {
                let dy = dy.expect("act grad");
                let y = Entry::new(nid);
                let bid = graph.add_node(
                    Op::ActivationBackward { kind },
                    format!("{name}_backward"),
                    vec![dy, y],
                );
                partials.entry(inputs[0]).or_default().push(Entry::new(bid));
            }
            Op::Pooling { kind, kernel, stride, pad } => {
                let dy = dy.expect("pool grad");
                let argmax = Entry { node: nid, out: 1 };
                let bid = graph.add_node(
                    Op::PoolingBackward { kind, kernel, stride, pad },
                    format!("{name}_backward"),
                    vec![dy, argmax, inputs[0]],
                );
                partials.entry(inputs[0]).or_default().push(Entry::new(bid));
            }
            Op::BatchNorm { .. } => {
                let dy = dy.expect("bn grad");
                let mean = Entry { node: nid, out: 1 };
                let invstd = Entry { node: nid, out: 2 };
                let bid = graph.add_node(
                    Op::BatchNormBackward,
                    format!("{name}_backward"),
                    vec![dy, inputs[0], inputs[1], mean, invstd],
                );
                partials.entry(inputs[0]).or_default().push(Entry { node: bid, out: 0 });
                partials.entry(inputs[1]).or_default().push(Entry { node: bid, out: 1 });
                partials.entry(inputs[2]).or_default().push(Entry { node: bid, out: 2 });
            }
            Op::Flatten => {
                let dy = dy.expect("flatten grad");
                let bid = graph.add_node(
                    Op::FlattenBackward,
                    format!("{name}_backward"),
                    vec![dy, inputs[0]],
                );
                partials.entry(inputs[0]).or_default().push(Entry::new(bid));
            }
            Op::Elemwise { op: ew } => {
                let dy = dy.expect("elemwise grad");
                match ew {
                    EwBinary::Add => {
                        partials.entry(inputs[0]).or_default().push(dy);
                        partials.entry(inputs[1]).or_default().push(dy);
                    }
                    EwBinary::Sub => {
                        partials.entry(inputs[0]).or_default().push(dy);
                        let neg = graph.add_node(
                            Op::MulScalar { s: -1.0 },
                            format!("{name}_bwd_neg"),
                            vec![dy],
                        );
                        partials.entry(inputs[1]).or_default().push(Entry::new(neg));
                    }
                    EwBinary::Mul => {
                        let da = graph.add_node(
                            Op::Elemwise { op: EwBinary::Mul },
                            format!("{name}_bwd_da"),
                            vec![dy, inputs[1]],
                        );
                        let db = graph.add_node(
                            Op::Elemwise { op: EwBinary::Mul },
                            format!("{name}_bwd_db"),
                            vec![dy, inputs[0]],
                        );
                        partials.entry(inputs[0]).or_default().push(Entry::new(da));
                        partials.entry(inputs[1]).or_default().push(Entry::new(db));
                    }
                    EwBinary::Div => {
                        // da = dy / b ; db = -dy * a / b^2 = -(da * y) where
                        // y = a/b is this node's output.
                        let da = graph.add_node(
                            Op::Elemwise { op: EwBinary::Div },
                            format!("{name}_bwd_da"),
                            vec![dy, inputs[1]],
                        );
                        let day = graph.add_node(
                            Op::Elemwise { op: EwBinary::Mul },
                            format!("{name}_bwd_day"),
                            vec![Entry::new(da), Entry::new(nid)],
                        );
                        let db = graph.add_node(
                            Op::MulScalar { s: -1.0 },
                            format!("{name}_bwd_db"),
                            vec![Entry::new(day)],
                        );
                        partials.entry(inputs[0]).or_default().push(Entry::new(da));
                        partials.entry(inputs[1]).or_default().push(Entry::new(db));
                    }
                }
            }
            Op::AddScalar { .. } => {
                let dy = dy.expect("addscalar grad");
                partials.entry(inputs[0]).or_default().push(dy);
            }
            Op::MulScalar { s } => {
                let dy = dy.expect("mulscalar grad");
                let bid =
                    graph.add_node(Op::MulScalar { s }, format!("{name}_bwd"), vec![dy]);
                partials.entry(inputs[0]).or_default().push(Entry::new(bid));
            }
            Op::Identity => {
                let dy = dy.expect("identity grad");
                partials.entry(inputs[0]).or_default().push(dy);
            }
            Op::AddN => {
                let dy = dy.expect("addn grad");
                for &inp in &inputs {
                    partials.entry(inp).or_default().push(dy);
                }
            }
            Op::Concat => {
                let dy = dy.expect("concat grad");
                let mut bins = vec![dy];
                bins.extend(inputs.iter().copied());
                let bid =
                    graph.add_node(Op::ConcatBackward, format!("{name}_backward"), bins);
                for (i, &inp) in inputs.iter().enumerate() {
                    partials.entry(inp).or_default().push(Entry { node: bid, out: i });
                }
            }
            Op::Dropout { .. } => {
                let dy = dy.expect("dropout grad");
                let mask = Entry { node: nid, out: 1 };
                let bid = graph.add_node(
                    Op::DropoutBackward,
                    format!("{name}_backward"),
                    vec![dy, mask],
                );
                partials.entry(inputs[0]).or_default().push(Entry::new(bid));
            }
            Op::FusedElemwise { .. } => {
                return Err(Error::graph(
                    "FusedElemwise appears before autodiff; fuse after building backward",
                ));
            }
            // Backward-of-backward unsupported (paper doesn't need it).
            _ => {
                return Err(Error::graph(format!(
                    "cannot differentiate through {}",
                    op.type_name()
                )));
            }
        }
    }

    // Materialize variable gradients.
    let mut var_grads = HashMap::new();
    for &vid in wrt {
        if !graph.nodes[vid].op.is_variable() {
            return Err(Error::graph(format!("node {vid} is not a variable")));
        }
        let e = Entry::new(vid);
        if let Some(parts) = partials.remove(&e) {
            let g = reduce(graph, e, parts);
            var_grads.insert(vid, g);
        }
    }
    Ok(GradInfo { var_grads })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tests::mlp_graph;
    use crate::graph::infer_shapes;

    #[test]
    fn mlp_backward_produces_all_param_grads() {
        let (mut g, vs) = mlp_graph(16);
        let params: Vec<NodeId> = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"]
            .iter()
            .map(|n| g.find_variable(n).unwrap())
            .collect();
        let gi = build_backward(&mut g, &params).unwrap();
        assert_eq!(gi.var_grads.len(), 4);
        g.validate().unwrap();
        // Shapes of gradients match parameter shapes.
        let shapes = infer_shapes(&g, &vs).unwrap();
        for (&vid, &ge) in &gi.var_grads {
            assert_eq!(shapes[vid][0], shapes[ge.node][ge.out], "grad shape mismatch");
        }
        assert!(g.num_forward < g.nodes.len());
    }

    #[test]
    fn data_grad_available_too() {
        let (mut g, _vs) = mlp_graph(4);
        let data = g.find_variable("data").unwrap();
        let gi = build_backward(&mut g, &[data]).unwrap();
        assert!(gi.var_grads.contains_key(&data));
    }

    #[test]
    fn no_softmax_head_errors() {
        let mut g = Graph::new();
        let a = g.add_variable("a");
        let b = g.add_node(Op::AddScalar { s: 1.0 }, "b", vec![Entry::new(a)]);
        g.outputs = vec![Entry::new(b)];
        assert!(build_backward(&mut g, &[a]).is_err());
    }

    #[test]
    fn fanout_grads_summed_with_addn() {
        // y = softmax(fc(x + x)): x used twice via Elemwise Add of the
        // same entry -> grads must be summed.
        use crate::ndarray::kernels::EwBinary;
        let mut g = Graph::new();
        let x = g.add_variable("x");
        let w = g.add_variable("w");
        let b = g.add_variable("b");
        let label = g.add_variable("label");
        let two_x = g.add_node(
            Op::Elemwise { op: EwBinary::Add },
            "twox",
            vec![Entry::new(x), Entry::new(x)],
        );
        let fc = g.add_node(
            Op::FullyConnected { num_hidden: 4, epilogue: vec![] },
            "fc",
            vec![Entry::new(two_x), Entry::new(w), Entry::new(b)],
        );
        let sm =
            g.add_node(Op::SoftmaxOutput, "sm", vec![Entry::new(fc), Entry::new(label)]);
        g.outputs = vec![Entry::new(sm)];
        let gi = build_backward(&mut g, &[x]).unwrap();
        let ge = gi.var_grads[&x];
        // Two partials (dy flows twice through Add) must be AddN-reduced.
        assert!(matches!(g.nodes[ge.node].op, Op::AddN));
        g.validate().unwrap();
    }
}

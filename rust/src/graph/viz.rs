//! Graph visualization (paper §2.1 lists visualization among symbol
//! utilities): Graphviz-dot emission for debugging and docs.

use super::Graph;

/// Render the graph in Graphviz dot format.  Backward nodes get a gray
/// fill like Figure 4's shading; recompute clones (the checkpointing
/// rewrite's mirror nodes) are dashed and labelled.
pub fn to_dot(graph: &Graph) -> String {
    let mut s = String::from("digraph mixnet {\n  rankdir=BT;\n  node [shape=box, fontsize=10];\n");
    for (id, node) in graph.nodes.iter().enumerate() {
        let recompute = crate::graph::recompute::is_recompute_name(&node.name);
        let style = if node.op.is_variable() {
            "shape=ellipse, style=filled, fillcolor=lightblue"
        } else if recompute {
            "style=\"filled,dashed\", fillcolor=lightyellow"
        } else if graph.num_forward > 0 && id >= graph.num_forward {
            "style=filled, fillcolor=lightgray"
        } else {
            "style=filled, fillcolor=white"
        };
        // `label()` spells out fused epilogues (e.g. FullyConnected+relu)
        // so dumped graphs show what the compiler actually ran.
        s.push_str(&format!(
            "  n{id} [label=\"{}\\n{}{}\", {style}];\n",
            node.name,
            node.op.label(),
            if recompute { "\\n(recompute)" } else { "" }
        ));
    }
    for (id, node) in graph.nodes.iter().enumerate() {
        for e in &node.inputs {
            s.push_str(&format!("  n{} -> n{id};\n", e.node));
        }
        for c in &node.control_deps {
            s.push_str(&format!("  n{c} -> n{id} [style=dashed];\n"));
        }
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tests::mlp_graph;

    #[test]
    fn dot_contains_all_nodes_and_edges() {
        let (g, _) = mlp_graph(4);
        let dot = to_dot(&g);
        assert!(dot.starts_with("digraph"));
        for n in &g.nodes {
            assert!(dot.contains(&n.name), "missing {}", n.name);
        }
        assert!(dot.matches(" -> ").count() >= g.nodes.iter().map(|n| n.inputs.len()).sum());
    }

    #[test]
    fn dot_renders_recompute_clones_dashed() {
        use crate::graph::autodiff::build_backward;
        use crate::graph::recompute::{apply_recompute, segment_boundaries};
        let (mut g, vs) = mlp_graph(4);
        let wrt: Vec<_> = g
            .variables()
            .into_iter()
            .filter(|&id| {
                let n = &g.nodes[id].name;
                n != "data" && n != "label"
            })
            .collect();
        build_backward(&mut g, &wrt).unwrap();
        let shapes = crate::graph::infer_shapes(&g, &vs).unwrap();
        let b = segment_boundaries(&g, &shapes, 2);
        let (rg, _, info) = apply_recompute(&g, &shapes, &b).unwrap();
        let dot = to_dot(&rg);
        if info.recompute_nodes > 0 {
            assert!(dot.contains("(recompute)"), "{dot}");
            assert!(dot.contains("style=\"filled,dashed\""), "{dot}");
        } else {
            // Tiny MLP may have nothing droppable; the dot must then be
            // clone-free.
            assert!(!dot.contains("(recompute)"));
        }
    }

    #[test]
    fn dot_renders_fused_epilogue_labels() {
        // After epilogue fusion the fc1+relu node must render its chain.
        let (g, _) = mlp_graph(4);
        let (fused, _) = crate::graph::optimize::fuse_epilogue(&g, &[]);
        let dot = to_dot(&fused);
        assert!(dot.contains("FullyConnected+relu"), "{dot}");
        // the plain head FC keeps its unadorned label
        assert!(dot.contains("\\nFullyConnected\""), "{dot}");
    }
}

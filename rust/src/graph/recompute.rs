//! Gradient checkpointing (MXNet §3.1 "mirror" nodes): sublinear-memory
//! training by recompute-on-backward.
//!
//! The forward graph is cut into K contiguous segments.  Entries produced
//! strictly inside a segment (not a graph output, not consumed by a later
//! forward segment) are *droppable*: after the forward pass their storage
//! can be reused, because the rewritten graph recomputes them during the
//! backward pass from the segment's boundary checkpoints.  With the
//! default K ≈ √n split over per-entry bytes this keeps only O(√n) of the
//! activation footprint live across the forward/backward boundary, at the
//! cost of roughly one extra forward pass.
//!
//! The rewrite runs at bind time *after* the fusion passes
//! ([`crate::graph::optimize`]), so recompute clones of fused nodes carry
//! their epilogues and replay at full speed.  Clones are ordinary graph
//! nodes appended to the backward region (segment-k clones are emitted
//! immediately before the first backward node that needs them), so the
//! RunPlan compiler, the storage planner, and the engine need no special
//! cases: dropped activations simply lose their backward consumers and the
//! existing liveness co-share frees them at their last forward reader.
//!
//! Determinism: a clone runs the identical op at the identical step, so
//! stochastic ops that derive their draw from `(seed, step)` (Dropout)
//! reproduce bitwise, and training with recompute is bitwise identical to
//! training without it for any thread count and any segment count.

use std::collections::{HashMap, HashSet};

use crate::error::{Error, Result};

use super::{entry_bytes, Entry, Graph, Node, NodeId, ShapeMap};

/// Name suffix marking recompute clones.  The rewrite runs after every
/// renaming pass, so the suffix survives into viz / profiler spans.
pub const RC_SUFFIX: &str = "_rc";

/// True if `name` names a recompute clone synthesized by [`apply_recompute`].
pub fn is_recompute_name(name: &str) -> bool {
    name.ends_with(RC_SUFFIX)
}

/// Memory-optimization mode for a bind (`BindConfig.memopt`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MemOpt {
    /// Keep every activation live until its backward consumer (baseline).
    #[default]
    Off,
    /// Drop interior activations after forward and recompute them during
    /// backward.  `segments == 0` means the automatic √n heuristic.
    Recompute { segments: usize },
}

impl MemOpt {
    /// Parse a CLI/env spec: `off` | `recompute` | `recompute:K`.
    pub fn parse(spec: &str) -> Result<MemOpt> {
        let s = spec.trim();
        match s {
            "off" | "none" => Ok(MemOpt::Off),
            "recompute" => Ok(MemOpt::Recompute { segments: 0 }),
            _ => {
                if let Some(k) = s.strip_prefix("recompute:") {
                    let segments: usize = k
                        .parse()
                        .map_err(|_| Error::graph(format!("bad --memopt segment count '{k}'")))?;
                    if segments == 1 {
                        return Err(Error::graph(
                            "--memopt recompute:1 is a no-op; use 'off' or >= 2 segments",
                        ));
                    }
                    Ok(MemOpt::Recompute { segments })
                } else {
                    Err(Error::graph(format!(
                        "bad --memopt '{s}' (expected off | recompute | recompute:K)"
                    )))
                }
            }
        }
    }

    /// Read the `PALLAS_MEMOPT` knob; `None` when unset or empty.
    /// Malformed values are reported on stderr and ignored.
    pub fn from_env() -> Option<MemOpt> {
        let v = std::env::var("PALLAS_MEMOPT").ok()?;
        if v.trim().is_empty() {
            return None;
        }
        match MemOpt::parse(&v) {
            Ok(m) => Some(m),
            Err(e) => {
                eprintln!("warning: ignoring PALLAS_MEMOPT: {e}");
                None
            }
        }
    }
}

impl std::fmt::Display for MemOpt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemOpt::Off => write!(f, "off"),
            MemOpt::Recompute { segments: 0 } => write!(f, "recompute"),
            MemOpt::Recompute { segments } => write!(f, "recompute:{segments}"),
        }
    }
}

/// What the rewrite did, for reporting and tests.
#[derive(Debug, Clone, Default)]
pub struct RecomputeInfo {
    /// Number of checkpoint segments the forward graph was cut into.
    pub segments: usize,
    /// Last forward node id of each segment (the checkpoint boundaries).
    pub boundaries: Vec<NodeId>,
    /// Recompute clone nodes appended to the backward region.
    pub recompute_nodes: usize,
    /// Forward entries whose originals no longer reach the backward pass.
    pub dropped_entries: usize,
    /// Bytes of those entries: activation memory no longer live across the
    /// forward/backward boundary.
    pub dropped_bytes: usize,
}

/// Cut the forward compute nodes into `segments` contiguous runs of
/// roughly equal output bytes and return the last node id of each run.
/// `segments == 0` selects K = max(2, round(√n)) over the n compute nodes.
/// Returns fewer than 2 boundaries when the graph is too small to cut (in
/// which case [`apply_recompute`] is an identity).
///
/// Each cut minimizes `bytes(node) + |cum(node) - quantile|`: a boundary
/// node's outputs become checkpoints that stay live until their segment's
/// backward runs, so its bytes are pure retained cost, while deviation
/// from the 1/K quantile grows some segment's recompute live-set by the
/// same number of bytes.  The additive score lets a pyramid's cut skip
/// past a huge conv output to the max-pool right after it, without
/// drifting to a far-away tiny head node and unbalancing the segments.
pub fn segment_boundaries(graph: &Graph, shapes: &ShapeMap, segments: usize) -> Vec<NodeId> {
    let nf = graph.num_forward;
    let ids: Vec<NodeId> = (0..nf).filter(|&id| !graph.nodes[id].op.is_variable()).collect();
    let n = ids.len();
    if n < 2 {
        return Vec::new();
    }
    let k = if segments == 0 {
        ((n as f64).sqrt().round() as usize).max(2)
    } else {
        segments
    }
    .min(n);
    if k < 2 {
        return Vec::new();
    }
    // Per-node weight: bytes of everything the node writes.  Weight floor 1
    // keeps zero-byte nodes from collapsing a segment.
    let weights: Vec<f64> = ids
        .iter()
        .map(|&id| {
            let b: usize = (0..graph.num_outputs_of(id))
                .map(|o| entry_bytes(&shapes[id][o]))
                .sum();
            (b.max(1)) as f64
        })
        .collect();
    let total: f64 = weights.iter().sum();
    let cums: Vec<f64> = weights
        .iter()
        .scan(0.0, |acc, w| {
            *acc += w;
            Some(*acc)
        })
        .collect();
    let mut bounds = Vec::with_capacity(k);
    let mut prev: Option<usize> = None;
    for j in 1..k {
        let target = total * j as f64 / k as f64;
        // Feasible cut indices: strictly after the previous cut, leaving
        // one node for each remaining cut plus the final segment.
        let lo = prev.map_or(0, |p| p + 1);
        let hi = n - 1 - (k - j);
        // Checkpoint bytes and quantile deviation both land in the retained
        // set byte-for-byte, so one additive score trades them off directly
        // (deviation breaks exact ties toward balance).
        let score = |i: usize| weights[i] + (cums[i] - target).abs();
        let mut best = lo;
        for i in lo + 1..=hi {
            let better = score(i) < score(best)
                || (score(i) == score(best)
                    && (cums[i] - target).abs() < (cums[best] - target).abs());
            if better {
                best = i;
            }
        }
        bounds.push(ids[best]);
        prev = Some(best);
    }
    bounds.push(*ids.last().unwrap());
    bounds
}

/// Rewrite `graph` so that interior activations of every segment except the
/// last are dropped after forward and recomputed during backward.
///
/// `boundaries` holds the last forward node id of each segment (from
/// [`segment_boundaries`] or an explicit per-node override).  Forward nodes
/// keep their ids; backward nodes are re-emitted with segment-k recompute
/// clones spliced in immediately before the first backward node that reads
/// a dropped entry of segment k.
///
/// Returns the rewritten graph, a map from every old entry to its new
/// entry (callers must remap gradient entries through it), and a
/// [`RecomputeInfo`] summary.  With fewer than 2 boundaries, no backward
/// region, or nothing droppable, the rewrite is an identity.
pub fn apply_recompute(
    graph: &Graph,
    shapes: &ShapeMap,
    boundaries: &[NodeId],
) -> Result<(Graph, HashMap<Entry, Entry>, RecomputeInfo)> {
    let nf = graph.num_forward;
    let n = graph.nodes.len();
    let identity = |g: &Graph| {
        let mut emap = HashMap::new();
        for id in 0..n {
            for o in 0..g.num_outputs_of(id) {
                let e = Entry { node: id, out: o };
                emap.insert(e, e);
            }
        }
        (g.clone(), emap, RecomputeInfo::default())
    };
    if nf == 0 || nf >= n || boundaries.len() < 2 {
        return Ok(identity(graph));
    }
    for w in boundaries.windows(2) {
        if w[1] <= w[0] {
            return Err(Error::graph("recompute boundaries must be strictly increasing"));
        }
    }
    if *boundaries.last().unwrap() >= nf {
        return Err(Error::graph("recompute boundary beyond the forward region"));
    }

    let nseg = boundaries.len();
    // seg_of[id]: which segment a forward node falls in (boundary = last
    // node of its segment; anything after the final boundary joins it).
    let mut seg_of = vec![0usize; nf];
    let mut s = 0usize;
    for (id, slot) in seg_of.iter_mut().enumerate() {
        *slot = s.min(nseg - 1);
        if s < nseg && id == boundaries[s.min(nseg - 1)] {
            s += 1;
        }
    }

    let outputs_set: HashSet<Entry> = graph.outputs.iter().copied().collect();
    // Which forward entries are read by the backward region, and which are
    // read by a *later* forward segment (those are checkpoints: kept).
    let mut bwd_used: HashSet<Entry> = HashSet::new();
    let mut later_fwd: HashSet<Entry> = HashSet::new();
    for (cid, node) in graph.nodes.iter().enumerate() {
        for e in &node.inputs {
            if e.node >= nf {
                continue;
            }
            if cid >= nf {
                bwd_used.insert(*e);
            } else if seg_of[cid] > seg_of[e.node] {
                later_fwd.insert(*e);
            }
        }
    }
    // Droppable: interior to a non-final segment.  The final segment is
    // never recomputed — its activations feed backward immediately, so
    // dropping them buys nothing.
    let droppable = |e: &Entry| -> bool {
        e.node < nf
            && !graph.nodes[e.node].op.is_variable()
            && seg_of[e.node] + 1 < nseg
            && !outputs_set.contains(e)
            && !later_fwd.contains(e)
    };

    // Clone set: nodes with a dropped-and-backward-needed output, closed
    // over droppable same-segment inputs (a clone can only read originals
    // that are still live at backward time — checkpoints and variables).
    let mut in_clone = vec![false; nf];
    for (id, node) in graph.nodes.iter().enumerate().take(nf) {
        if node.op.is_variable() {
            continue;
        }
        in_clone[id] = (0..graph.num_outputs_of(id)).any(|o| {
            let e = Entry { node: id, out: o };
            droppable(&e) && bwd_used.contains(&e)
        });
    }
    for id in (0..nf).rev() {
        if !in_clone[id] {
            continue;
        }
        for e in &graph.nodes[id].inputs {
            if droppable(e) {
                in_clone[e.node] = true;
            }
        }
    }
    let mut seg_nodes: Vec<Vec<NodeId>> = vec![Vec::new(); nseg];
    for (id, &m) in in_clone.iter().enumerate() {
        if m {
            seg_nodes[seg_of[id]].push(id);
        }
    }

    let mut info = RecomputeInfo {
        segments: nseg,
        boundaries: boundaries.to_vec(),
        ..RecomputeInfo::default()
    };
    for id in 0..nf {
        for o in 0..graph.num_outputs_of(id) {
            let e = Entry { node: id, out: o };
            if droppable(&e) && bwd_used.contains(&e) {
                info.dropped_entries += 1;
                info.dropped_bytes += entry_bytes(&shapes[id][o]);
            }
        }
    }
    if info.dropped_entries == 0 {
        return Ok(identity(graph));
    }

    // Rebuild: forward verbatim (ids preserved), then old backward nodes in
    // order with recompute blocks faulted in on first use of a dropped
    // entry from their segment.
    let mut out = Graph::new();
    out.nodes.extend(graph.nodes[..nf].iter().cloned());
    out.num_forward = nf;
    let mut emap: HashMap<Entry, Entry> = HashMap::new();
    for id in 0..nf {
        for o in 0..graph.num_outputs_of(id) {
            let e = Entry { node: id, out: o };
            emap.insert(e, e);
        }
    }
    // Old node id -> new node id (identity for forward, shifted for bwd).
    let mut node_map: Vec<NodeId> = (0..n).collect();
    // Old dropped entry -> its recompute clone's entry.
    let mut rcmap: HashMap<Entry, Entry> = HashMap::new();
    let mut emitted = vec![false; nseg];
    for id in nf..n {
        for e in &graph.nodes[id].inputs {
            if e.node >= nf || !droppable(e) {
                continue;
            }
            let k = seg_of[e.node];
            if emitted[k] {
                continue;
            }
            emitted[k] = true;
            for &fid in &seg_nodes[k] {
                let src = &graph.nodes[fid];
                let inputs: Vec<Entry> = src
                    .inputs
                    .iter()
                    .map(|ie| rcmap.get(ie).copied().unwrap_or(*ie))
                    .collect();
                let nid =
                    out.add_node(src.op.clone(), format!("{}{}", src.name, RC_SUFFIX), inputs);
                info.recompute_nodes += 1;
                for o in 0..graph.num_outputs_of(fid) {
                    let oe = Entry { node: fid, out: o };
                    if droppable(&oe) {
                        rcmap.insert(oe, Entry { node: nid, out: o });
                    }
                }
            }
        }
        let src = &graph.nodes[id];
        let inputs: Vec<Entry> = src
            .inputs
            .iter()
            .map(|ie| match rcmap.get(ie) {
                Some(&r) => r,
                None => emap[ie],
            })
            .collect();
        let control_deps: Vec<NodeId> = src.control_deps.iter().map(|&c| node_map[c]).collect();
        let nid = out.nodes.len();
        out.nodes.push(Node {
            op: src.op.clone(),
            name: src.name.clone(),
            inputs,
            control_deps,
        });
        node_map[id] = nid;
        for o in 0..graph.num_outputs_of(id) {
            emap.insert(Entry { node: id, out: o }, Entry { node: nid, out: o });
        }
    }
    out.outputs = graph.outputs.iter().map(|e| emap[e]).collect();
    out.validate()?;
    Ok((out, emap, info))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::build_backward;
    use crate::graph::infer_shapes;
    use crate::graph::memory::{default_external, plan_memory, validate_plan, AllocStrategy};
    use crate::symbol::{Act, Symbol};
    use std::collections::HashMap as Map;

    /// Deep MLP: enough fc+relu pairs that interior activations are
    /// dropped (FullyConnectedBackward reads x = the previous activation).
    fn deep_mlp(batch: usize) -> (Graph, Vec<NodeId>, Map<String, Vec<usize>>) {
        let dims = [32usize, 64, 48, 32, 16];
        let mut x = Symbol::var("data");
        for i in 0..4 {
            x = x
                .fully_connected(&format!("fc{i}"), dims[i + 1])
                .activation(&format!("relu{i}"), Act::Relu);
        }
        let net = x.fully_connected("out", 10).softmax_output("softmax");
        let graph = Symbol::to_graph(&[net]);
        let wrt: Vec<NodeId> = graph
            .variables()
            .into_iter()
            .filter(|&id| {
                let n = &graph.nodes[id].name;
                n != "data" && n != "softmax_label"
            })
            .collect();
        let mut vars = Map::new();
        vars.insert("data".to_string(), vec![batch, dims[0]]);
        vars.insert("softmax_label".to_string(), vec![batch]);
        for i in 0..4 {
            vars.insert(format!("fc{i}_weight"), vec![dims[i + 1], dims[i]]);
            vars.insert(format!("fc{i}_bias"), vec![dims[i + 1]]);
        }
        vars.insert("out_weight".to_string(), vec![10, dims[4]]);
        vars.insert("out_bias".to_string(), vec![10]);
        (graph, wrt, vars)
    }

    /// Graph with backward appended + gradient entries + shapes.
    fn trainable(batch: usize) -> (Graph, Vec<Entry>, ShapeMap, Map<String, Vec<usize>>) {
        let (mut g, wrt, vars) = deep_mlp(batch);
        let gi = build_backward(&mut g, &wrt).expect("backward");
        let grads: Vec<Entry> = gi.var_grads.values().copied().collect();
        let shapes = infer_shapes(&g, &vars).expect("shapes");
        (g, grads, shapes, vars)
    }

    #[test]
    fn parse_memopt_specs() {
        assert_eq!(MemOpt::parse("off").unwrap(), MemOpt::Off);
        assert_eq!(MemOpt::parse("none").unwrap(), MemOpt::Off);
        assert_eq!(MemOpt::parse("recompute").unwrap(), MemOpt::Recompute { segments: 0 });
        assert_eq!(MemOpt::parse(" recompute:4 ").unwrap(), MemOpt::Recompute { segments: 4 });
        assert!(MemOpt::parse("recompute:1").is_err());
        assert!(MemOpt::parse("recompute:x").is_err());
        assert!(MemOpt::parse("mirrors").is_err());
        assert_eq!(MemOpt::Recompute { segments: 3 }.to_string(), "recompute:3");
        assert_eq!(MemOpt::Recompute { segments: 0 }.to_string(), "recompute");
    }

    #[test]
    fn boundaries_are_strict_and_sized() {
        let (g, _, shapes, _) = trainable(8);
        // 10 forward compute nodes: 5 fc, 4 relu, softmax.
        for k in [0usize, 2, 3, 4, 10, 64] {
            let b = segment_boundaries(&g, &shapes, k);
            assert!(b.len() >= 2, "k={k} gave {b:?}");
            for w in b.windows(2) {
                assert!(w[1] > w[0], "k={k}: {b:?}");
            }
            assert!(*b.last().unwrap() < g.num_forward);
            if (2..=10).contains(&k) {
                assert_eq!(b.len(), k, "k={k}: {b:?}");
            }
            if k > 10 {
                assert_eq!(b.len(), 10, "clamped to compute-node count: {b:?}");
            }
        }
    }

    #[test]
    fn rewrite_validates_and_marks_clones() {
        let (g, grads, shapes, _) = trainable(8);
        let b = segment_boundaries(&g, &shapes, 3);
        let (rg, emap, info) = apply_recompute(&g, &shapes, &b).expect("rewrite");
        rg.validate().expect("valid");
        assert_eq!(rg.num_forward, g.num_forward);
        assert!(info.recompute_nodes > 0, "{info:?}");
        assert!(info.dropped_bytes > 0, "{info:?}");
        let rc = rg.nodes.iter().filter(|n| is_recompute_name(&n.name)).count();
        assert_eq!(rc, info.recompute_nodes);
        for (id, node) in rg.nodes.iter().enumerate() {
            if is_recompute_name(&node.name) {
                assert!(id >= rg.num_forward, "clone {id} in forward region");
            }
        }
        for e in &grads {
            let m = emap[e];
            assert!(m.node < rg.nodes.len());
            // Gradients are produced by backward math nodes, never clones.
            assert!(!is_recompute_name(&rg.nodes[m.node].name));
        }
    }

    #[test]
    fn dropped_entries_have_no_backward_readers() {
        let (g, _, shapes, _) = trainable(8);
        let b = segment_boundaries(&g, &shapes, 3);
        let (rg, _, info) = apply_recompute(&g, &shapes, &b).expect("rewrite");
        assert!(info.dropped_entries > 0);
        // Reconstruct droppability on the rewritten graph (forward region
        // is id-identical): no node at or past num_forward may read a
        // dropped forward entry — it must read the clone instead.
        let nf = rg.num_forward;
        let nseg = info.boundaries.len();
        let mut seg_of = vec![0usize; nf];
        let mut s = 0usize;
        for (id, slot) in seg_of.iter_mut().enumerate() {
            *slot = s.min(nseg - 1);
            if s < nseg && id == info.boundaries[s.min(nseg - 1)] {
                s += 1;
            }
        }
        let outputs: HashSet<Entry> = rg.outputs.iter().copied().collect();
        let mut later_fwd: HashSet<Entry> = HashSet::new();
        for (cid, node) in rg.nodes.iter().enumerate().take(nf) {
            for e in &node.inputs {
                if e.node < nf && seg_of[cid] > seg_of[e.node] {
                    later_fwd.insert(*e);
                }
            }
        }
        for (cid, node) in rg.nodes.iter().enumerate().skip(nf) {
            for e in &node.inputs {
                if e.node >= nf {
                    continue;
                }
                let dropped = !rg.nodes[e.node].op.is_variable()
                    && seg_of[e.node] + 1 < nseg
                    && !outputs.contains(e)
                    && !later_fwd.contains(e);
                assert!(
                    !dropped,
                    "backward node {cid} ({}) reads dropped forward entry {e:?}",
                    node.name
                );
            }
        }
    }

    #[test]
    fn planned_peak_shrinks_under_recompute() {
        let (g, grads, shapes, vars) = trainable(64);
        let ext = default_external(&g, &grads);
        let base = plan_memory(&g, &shapes, &ext, AllocStrategy::Both);
        validate_plan(&g, &shapes, &ext, &base).expect("baseline plan");
        assert!(base.peak_bytes > 0 && base.peak_bytes <= base.total_internal_bytes);
        for k in [2usize, 3, 4, 5] {
            let b = segment_boundaries(&g, &shapes, k);
            let (rg, emap, _) = apply_recompute(&g, &shapes, &b).expect("rewrite");
            let grads2: Vec<Entry> = grads.iter().map(|e| emap[e]).collect();
            let shapes2 = infer_shapes(&rg, &vars).expect("shapes");
            let ext2 = default_external(&rg, &grads2);
            let plan = plan_memory(&rg, &shapes2, &ext2, AllocStrategy::Both);
            validate_plan(&rg, &shapes2, &ext2, &plan).expect("recompute plan");
            // Monotone bound: never worse than keeping everything live.
            assert!(
                plan.peak_bytes <= base.total_internal_bytes,
                "k={k}: peak {} > dedicated total {}",
                plan.peak_bytes,
                base.total_internal_bytes
            );
        }
    }

    #[test]
    fn identity_when_nothing_droppable() {
        // fc -> softmax: everything is a checkpoint, an output, or final
        // segment, so the rewrite must be an identity.
        let net = Symbol::var("data").fully_connected("fc", 4).softmax_output("softmax");
        let mut g = Symbol::to_graph(&[net]);
        let wrt: Vec<NodeId> = g
            .variables()
            .into_iter()
            .filter(|&id| {
                let n = &g.nodes[id].name;
                n != "data" && n != "softmax_label"
            })
            .collect();
        build_backward(&mut g, &wrt).expect("backward");
        let mut vars = Map::new();
        vars.insert("data".to_string(), vec![2, 8]);
        vars.insert("softmax_label".to_string(), vec![2]);
        vars.insert("fc_weight".to_string(), vec![4, 8]);
        vars.insert("fc_bias".to_string(), vec![4]);
        let shapes = infer_shapes(&g, &vars).expect("shapes");
        let b = segment_boundaries(&g, &shapes, 2);
        let (rg, _, info) = apply_recompute(&g, &shapes, &b).expect("rewrite");
        assert_eq!(info.recompute_nodes, 0);
        assert_eq!(rg.nodes.len(), g.nodes.len());
    }

    #[test]
    fn clones_preserve_op_kind() {
        let (g, _, shapes, _) = trainable(8);
        let b = segment_boundaries(&g, &shapes, 4);
        let (rg, _, _) = apply_recompute(&g, &shapes, &b).expect("rewrite");
        for node in &rg.nodes {
            if let Some(orig) = node.name.strip_suffix(RC_SUFFIX) {
                let src = rg
                    .nodes
                    .iter()
                    .find(|n| n.name == orig)
                    .unwrap_or_else(|| panic!("clone {} has no source", node.name));
                assert_eq!(
                    std::mem::discriminant(&src.op),
                    std::mem::discriminant(&node.op),
                    "clone {} changed op kind",
                    node.name
                );
            }
        }
    }
}

//! Graph memory allocation (paper §3.1, evaluated in Figure 7).
//!
//! Each internal entry's lifetime — creation to last use — is known once
//! the graph is fixed, so storage can be reused across entries whose
//! lifetimes do not intersect.  An optimal assignment costs `O(n^2)`; the
//! paper proposes two linear-time heuristics, both implemented here:
//!
//! * **inplace** — simulate graph traversal keeping a reference count per
//!   entry; when an op supports identity layout (activations, elementwise,
//!   flatten, gradient pass-throughs) and its input dies at this node, the
//!   output reuses the input's buffer.
//! * **co-share** — entries whose lifetimes are disjoint in the simulated
//!   schedule share one buffer drawn from a free pool; sharing imposes an
//!   extra serialization constraint, recorded as a control dependency
//!   (the executor also gets it for free: co-tenants write the same
//!   storage tag, so the engine serializes them in program order).
//!
//! `Both` composes them, which is what MXNet ships; the paper reports ~2x
//! internal-memory reduction for training and ~4x for prediction — the
//! `fig7_memory` bench regenerates that comparison with these exact
//! planners.

use std::collections::{HashMap, HashSet};

use super::{entry_bytes, workspace_bytes, Entry, Graph, NodeId, ShapeMap};

/// Allocation strategy selector (the four Figure 7 series).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AllocStrategy {
    /// Every internal entry gets dedicated storage.
    None,
    /// Inplace identity reuse only.
    Inplace,
    /// Free-pool co-sharing only.
    CoShare,
    /// Inplace + co-share (MXNet default).
    Both,
}

impl AllocStrategy {
    /// All strategies, in Figure 7 presentation order.
    pub fn all() -> [AllocStrategy; 4] {
        [AllocStrategy::None, AllocStrategy::Inplace, AllocStrategy::CoShare, AllocStrategy::Both]
    }

    fn inplace(self) -> bool {
        matches!(self, AllocStrategy::Inplace | AllocStrategy::Both)
    }

    fn coshare(self) -> bool {
        matches!(self, AllocStrategy::CoShare | AllocStrategy::Both)
    }
}

impl std::fmt::Display for AllocStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            AllocStrategy::None => "none",
            AllocStrategy::Inplace => "inplace",
            AllocStrategy::CoShare => "co-share",
            AllocStrategy::Both => "both",
        };
        write!(f, "{s}")
    }
}

/// A storage assignment for the internal entries of a graph.
#[derive(Debug, Clone)]
pub struct MemPlan {
    /// Storage id per internal entry.
    pub storage_of: HashMap<Entry, usize>,
    /// Byte size of each storage block.
    pub storage_bytes: Vec<usize>,
    /// Workspace storage id per node that needs scratch space.
    pub workspace_of: HashMap<NodeId, usize>,
    /// Total bytes across storage blocks — the Figure 7 metric
    /// ("internal variables except for the outputs").
    pub total_internal_bytes: usize,
    /// Maximum bytes of simultaneously-occupied storage blocks during the
    /// planned walk.  With recompute rewrites this is the headline metric:
    /// dropped activations leave tenancy at their last forward reader, so
    /// the peak shrinks even though `total_internal_bytes` counts every
    /// block once.
    pub peak_bytes: usize,
    /// Extra ordering constraints implied by sharing: `(later, earlier)`.
    pub control_deps: Vec<(NodeId, NodeId)>,
}

impl MemPlan {
    /// Bytes that a dedicated-everything plan would use (for ratio
    /// reporting).
    pub fn bytes_mb(&self) -> f64 {
        crate::util::mb(self.total_internal_bytes)
    }

    /// Element count of each storage block, in block-id order — what the
    /// executor materializes.  Each co-share tag maps onto one pooled
    /// slot: blocks are drawn from the process-wide storage pool
    /// ([`crate::ndarray::pool`]) without zero-fill, and because a bound
    /// graph re-requests the exact same sizes on every rebind, a warm
    /// pool serves them all as hits.
    pub fn storage_elems(&self) -> impl Iterator<Item = usize> + '_ {
        self.storage_bytes.iter().map(|&b| b / 4)
    }
}

/// Plan storage for every internal entry of `graph`.
///
/// `external` entries (variable outputs, requested graph outputs,
/// gradient outputs read by the optimizer) are excluded from planning and
/// from the byte total, matching the paper's metric.
pub fn plan_memory(
    graph: &Graph,
    shapes: &ShapeMap,
    external: &HashSet<Entry>,
    strategy: AllocStrategy,
) -> MemPlan {
    let ws_bytes = workspace_bytes(graph, shapes);
    let mut rc = graph.entry_refcounts(&[]);
    // External entries never die for planning purposes.
    for e in external {
        rc.insert(*e, usize::MAX / 2);
    }

    let mut storage_of: HashMap<Entry, usize> = HashMap::new();
    let mut storage_bytes: Vec<usize> = Vec::new();
    let mut storage_refs: Vec<usize> = Vec::new();
    let mut last_releaser: Vec<Option<NodeId>> = Vec::new();
    let mut workspace_of: HashMap<NodeId, usize> = HashMap::new();
    let mut control_deps: Vec<(NodeId, NodeId)> = Vec::new();
    // Free pool: (bytes, storage id); kept sorted by bytes for best-fit.
    let mut pool: Vec<(usize, usize)> = Vec::new();
    // High-water mark of simultaneously-occupied block bytes.
    let mut occupied: usize = 0;
    let mut peak_bytes: usize = 0;

    let is_internal =
        |e: &Entry, graph: &Graph| !external.contains(e) && !graph.nodes[e.node].op.is_variable();

    let alloc = |bytes: usize,
                     node: NodeId,
                     pool: &mut Vec<(usize, usize)>,
                     storage_bytes: &mut Vec<usize>,
                     storage_refs: &mut Vec<usize>,
                     last_releaser: &mut Vec<Option<NodeId>>,
                     control_deps: &mut Vec<(NodeId, NodeId)>,
                     coshare: bool|
     -> usize {
        if coshare {
            // best fit >= bytes
            if let Some(pos) = pool
                .iter()
                .enumerate()
                .filter(|(_, (b, _))| *b >= bytes)
                .min_by_key(|(_, (b, _))| *b)
                .map(|(i, _)| i)
            {
                let (_, sid) = pool.remove(pos);
                if let Some(rel) = last_releaser[sid] {
                    control_deps.push((node, rel));
                }
                storage_refs[sid] += 1;
                return sid;
            }
            // else grow the largest free block (reduces total footprint
            // versus always allocating fresh).
            if let Some(pos) = pool
                .iter()
                .enumerate()
                .max_by_key(|(_, (b, _))| *b)
                .map(|(i, _)| i)
            {
                let (_, sid) = pool.remove(pos);
                storage_bytes[sid] = bytes;
                if let Some(rel) = last_releaser[sid] {
                    control_deps.push((node, rel));
                }
                storage_refs[sid] += 1;
                return sid;
            }
        }
        storage_bytes.push(bytes);
        storage_refs.push(1);
        last_releaser.push(None);
        storage_bytes.len() - 1
    };

    for (nid, node) in graph.nodes.iter().enumerate() {
        if node.op.is_variable() {
            continue;
        }
        let nout = graph.num_outputs_of(nid);
        let mut taken_inputs: HashSet<usize> = HashSet::new();
        let mut assigned: HashSet<usize> = HashSet::new();

        // 1. inplace identity reuse
        if strategy.inplace() {
            for &(iidx, oidx) in node.op.inplace_pairs() {
                if oidx >= nout || assigned.contains(&oidx) || iidx >= node.inputs.len() {
                    continue;
                }
                let in_e = node.inputs[iidx];
                let out_e = Entry { node: nid, out: oidx };
                if external.contains(&out_e) || !is_internal(&in_e, graph) {
                    continue;
                }
                if taken_inputs.contains(&iidx) {
                    continue;
                }
                if rc.get(&in_e).copied().unwrap_or(0) != 1 {
                    continue; // input still needed elsewhere
                }
                let in_bytes = entry_bytes(&shapes[in_e.node][in_e.out]);
                let out_bytes = entry_bytes(&shapes[nid][oidx]);
                if in_bytes != out_bytes {
                    continue;
                }
                if let Some(&sid) = storage_of.get(&in_e) {
                    storage_of.insert(out_e, sid);
                    storage_refs[sid] += 1;
                    taken_inputs.insert(iidx);
                    assigned.insert(oidx);
                }
            }
        }

        // 2. allocate remaining internal outputs
        for oidx in 0..nout {
            if assigned.contains(&oidx) {
                continue;
            }
            let out_e = Entry { node: nid, out: oidx };
            if external.contains(&out_e) {
                continue;
            }
            let bytes = entry_bytes(&shapes[nid][oidx]);
            if bytes == 0 {
                continue;
            }
            let sid = alloc(
                bytes,
                nid,
                &mut pool,
                &mut storage_bytes,
                &mut storage_refs,
                &mut last_releaser,
                &mut control_deps,
                strategy.coshare(),
            );
            storage_of.insert(out_e, sid);
            occupied += storage_bytes[sid];
            peak_bytes = peak_bytes.max(occupied);
        }

        // 3. workspace for this node (lifetime = the node itself)
        if ws_bytes[nid] > 0 {
            let sid = alloc(
                ws_bytes[nid],
                nid,
                &mut pool,
                &mut storage_bytes,
                &mut storage_refs,
                &mut last_releaser,
                &mut control_deps,
                strategy.coshare(),
            );
            workspace_of.insert(nid, sid);
            occupied += storage_bytes[sid];
            peak_bytes = peak_bytes.max(occupied);
            // released immediately after the node runs
            storage_refs[sid] -= 1;
            if storage_refs[sid] == 0 {
                last_releaser[sid] = Some(nid);
                pool.push((storage_bytes[sid], sid));
                occupied -= storage_bytes[sid];
            }
        }

        // 4. inputs die after their last consumer
        let mut seen: HashSet<Entry> = HashSet::new();
        for e in &node.inputs {
            if !seen.insert(*e) {
                continue;
            }
            if let Some(c) = rc.get_mut(e) {
                *c = c.saturating_sub(1);
                if *c == 0 && is_internal(e, graph) {
                    if let Some(&sid) = storage_of.get(e) {
                        storage_refs[sid] -= 1;
                        if storage_refs[sid] == 0 {
                            last_releaser[sid] = Some(nid);
                            pool.push((storage_bytes[sid], sid));
                            occupied -= storage_bytes[sid];
                        }
                    }
                }
            }
        }

        // 5. outputs nobody consumes die immediately (e.g. pooling argmax
        // in a forward-only graph)
        for oidx in 0..nout {
            let out_e = Entry { node: nid, out: oidx };
            if external.contains(&out_e) {
                continue;
            }
            if rc.get(&out_e).copied().unwrap_or(0) == 0 {
                if let Some(&sid) = storage_of.get(&out_e) {
                    storage_refs[sid] -= 1;
                    if storage_refs[sid] == 0 {
                        last_releaser[sid] = Some(nid);
                        pool.push((storage_bytes[sid], sid));
                        occupied -= storage_bytes[sid];
                    }
                }
            }
        }
    }

    let total_internal_bytes = storage_bytes.iter().sum();
    MemPlan {
        storage_of,
        storage_bytes,
        workspace_of,
        total_internal_bytes,
        peak_bytes,
        control_deps,
    }
}

/// The default external set for an executor: all variable outputs, all
/// graph outputs, plus any extra entries (e.g. variable gradients).
pub fn default_external(graph: &Graph, extra: &[Entry]) -> HashSet<Entry> {
    let mut ext: HashSet<Entry> = HashSet::new();
    for (id, n) in graph.nodes.iter().enumerate() {
        if n.op.is_variable() {
            ext.insert(Entry::new(id));
        }
    }
    for e in &graph.outputs {
        ext.insert(*e);
    }
    for e in extra {
        ext.insert(*e);
    }
    ext
}

/// Verify a plan never lets two simultaneously-live entries share storage
/// (used by tests and the property suite).
pub fn validate_plan(
    graph: &Graph,
    shapes: &ShapeMap,
    external: &HashSet<Entry>,
    plan: &MemPlan,
) -> Result<(), String> {
    // Re-simulate with per-storage current tenant; a storage may host a new
    // tenant only when the previous one is dead (refcount satisfied) or via
    // the inplace pair of the consuming node.
    let mut rc = graph.entry_refcounts(&[]);
    for e in external {
        rc.insert(*e, usize::MAX / 2);
    }
    let mut live_of_storage: HashMap<usize, HashSet<Entry>> = HashMap::new();
    for (nid, node) in graph.nodes.iter().enumerate() {
        if node.op.is_variable() {
            continue;
        }
        // outputs become live
        for oidx in 0..graph.num_outputs_of(nid) {
            let e = Entry { node: nid, out: oidx };
            if let Some(&sid) = plan.storage_of.get(&e) {
                let live = live_of_storage.entry(sid).or_default();
                // the only allowed co-residents are inputs of THIS node
                // being consumed inplace
                for other in live.iter() {
                    let is_own_input = node.inputs.contains(other);
                    if !is_own_input {
                        return Err(format!(
                            "storage {sid} hosts {other:?} while {e:?} is written by node {nid}"
                        ));
                    }
                }
                if entry_bytes(&shapes[e.node][e.out]) > plan.storage_bytes[sid] {
                    return Err(format!("entry {e:?} exceeds storage {sid}"));
                }
                live.insert(e);
            }
        }
        // inputs die
        let mut seen = HashSet::new();
        for e in &node.inputs {
            if !seen.insert(*e) {
                continue;
            }
            if let Some(c) = rc.get_mut(e) {
                *c = c.saturating_sub(1);
                if *c == 0 {
                    if let Some(&sid) = plan.storage_of.get(e) {
                        live_of_storage.entry(sid).or_default().remove(e);
                    }
                }
            }
        }
        // dead-on-arrival outputs
        for oidx in 0..graph.num_outputs_of(nid) {
            let e = Entry { node: nid, out: oidx };
            if rc.get(&e).copied().unwrap_or(0) == 0 {
                if let Some(&sid) = plan.storage_of.get(&e) {
                    live_of_storage.entry(sid).or_default().remove(&e);
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::build_backward;
    use crate::graph::infer_shapes;
    use crate::graph::tests::mlp_graph;

    fn strategies_bytes(fwd_only: bool, batch: usize) -> HashMap<AllocStrategy, usize> {
        let (mut g, vs) = mlp_graph(batch);
        let mut extra = vec![];
        if !fwd_only {
            let params: Vec<_> = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"]
                .iter()
                .map(|n| g.find_variable(n).unwrap())
                .collect();
            let gi = build_backward(&mut g, &params).unwrap();
            extra.extend(gi.var_grads.values().copied());
        }
        let shapes = infer_shapes(&g, &vs).unwrap();
        let ext = default_external(&g, &extra);
        AllocStrategy::all()
            .into_iter()
            .map(|s| {
                let plan = plan_memory(&g, &shapes, &ext, s);
                validate_plan(&g, &shapes, &ext, &plan).unwrap();
                (s, plan.total_internal_bytes)
            })
            .collect()
    }

    #[test]
    fn strategies_monotone_improvement() {
        for fwd in [true, false] {
            let b = strategies_bytes(fwd, 64);
            assert!(b[&AllocStrategy::Inplace] <= b[&AllocStrategy::None]);
            assert!(b[&AllocStrategy::CoShare] <= b[&AllocStrategy::None]);
            assert!(b[&AllocStrategy::Both] <= b[&AllocStrategy::Inplace]);
            assert!(b[&AllocStrategy::Both] <= b[&AllocStrategy::CoShare]);
        }
    }

    #[test]
    fn inplace_reuses_activation_buffer() {
        // In the MLP forward, relu1 should share fc1's buffer under inplace.
        let (g, vs) = mlp_graph(8);
        let shapes = infer_shapes(&g, &vs).unwrap();
        let ext = default_external(&g, &[]);
        let plan = plan_memory(&g, &shapes, &ext, AllocStrategy::Inplace);
        let fc1 = g.nodes.iter().position(|n| n.name == "fc1").unwrap();
        let relu = g.nodes.iter().position(|n| n.name == "relu1").unwrap();
        assert_eq!(
            plan.storage_of[&Entry::new(fc1)],
            plan.storage_of[&Entry::new(relu)],
            "activation must run inplace"
        );
    }

    #[test]
    fn external_entries_not_planned() {
        let (g, vs) = mlp_graph(8);
        let shapes = infer_shapes(&g, &vs).unwrap();
        let ext = default_external(&g, &[]);
        let plan = plan_memory(&g, &shapes, &ext, AllocStrategy::Both);
        for e in &ext {
            assert!(!plan.storage_of.contains_key(e));
        }
    }

    #[test]
    fn coshare_never_exceeds_live_set() {
        let (mut g, vs) = mlp_graph(16);
        let params: Vec<_> = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"]
            .iter()
            .map(|n| g.find_variable(n).unwrap())
            .collect();
        let gi = build_backward(&mut g, &params).unwrap();
        let extra: Vec<_> = gi.var_grads.values().copied().collect();
        let shapes = infer_shapes(&g, &vs).unwrap();
        let ext = default_external(&g, &extra);
        for s in AllocStrategy::all() {
            let plan = plan_memory(&g, &shapes, &ext, s);
            validate_plan(&g, &shapes, &ext, &plan)
                .unwrap_or_else(|e| panic!("{s}: {e}"));
        }
    }

    #[test]
    fn prediction_saves_more_than_training() {
        // The paper's Figure 7 shape: forward-only (prediction) reduction
        // ratio >= training reduction ratio.
        let fwd = strategies_bytes(true, 64);
        let train = strategies_bytes(false, 64);
        let ratio_fwd = fwd[&AllocStrategy::None] as f64 / fwd[&AllocStrategy::Both] as f64;
        let ratio_train =
            train[&AllocStrategy::None] as f64 / train[&AllocStrategy::Both] as f64;
        assert!(
            ratio_fwd >= ratio_train * 0.9,
            "fwd {ratio_fwd:.2} vs train {ratio_train:.2}"
        );
    }
}

//! Graph optimization (paper §3.1).
//!
//! Two of the paper's three "straightforward optimizations" are graph
//! transforms implemented here (the third — hand-optimized big ops — lives
//! in the kernels):
//!
//! * [`prune`] — *"only the subgraph required to obtain the outputs
//!   specified during binding is needed"*: prediction drops the backward
//!   half; feature extraction drops the tail layers.
//! * [`fuse_elementwise`] — *"operators can be grouped into one"*: chains
//!   of elementwise ops (`a * b + 1`, scalar ops, activations) collapse
//!   into a single [`Op::FusedElemwise`] node, saving kernel dispatches
//!   and intermediate buffers.
//! * [`fuse_epilogue`] — runs after `fuse_elementwise` and folds the
//!   elementwise chain *following* a `FullyConnected` / `Convolution`
//!   node into the producer's epilogue, so bias+activation run inside
//!   the GEMM/conv kernel while the output tile is still cache-hot.

use std::collections::{HashMap, HashSet};

use super::{Entry, FusedStep, Graph, Node, NodeId, Op};

/// Remap table returned by graph rewrites: old node id -> new node id.
pub type NodeRemap = HashMap<NodeId, NodeId>;

/// Keep only the ancestors of `roots`, preserving relative order.
/// Returns the pruned graph and the node remap (dropped nodes absent).
pub fn prune(graph: &Graph, roots: &[Entry]) -> (Graph, NodeRemap) {
    let mut keep = vec![false; graph.nodes.len()];
    let mut stack: Vec<NodeId> = roots.iter().map(|e| e.node).collect();
    while let Some(n) = stack.pop() {
        if keep[n] {
            continue;
        }
        keep[n] = true;
        for e in &graph.nodes[n].inputs {
            stack.push(e.node);
        }
        for &c in &graph.nodes[n].control_deps {
            stack.push(c);
        }
    }
    let mut remap: NodeRemap = HashMap::new();
    let mut out = Graph::new();
    let mut num_forward = 0usize;
    for (id, node) in graph.nodes.iter().enumerate() {
        if !keep[id] {
            continue;
        }
        let inputs =
            node.inputs.iter().map(|e| Entry { node: remap[&e.node], out: e.out }).collect();
        let control_deps = node.control_deps.iter().map(|c| remap[c]).collect();
        let nid = out.nodes.len();
        out.nodes.push(Node {
            op: node.op.clone(),
            name: node.name.clone(),
            inputs,
            control_deps,
        });
        remap.insert(id, nid);
        if id < graph.num_forward {
            num_forward = nid + 1;
        }
    }
    out.outputs = roots
        .iter()
        .map(|e| Entry { node: remap[&e.node], out: e.out })
        .collect();
    out.num_forward = if graph.num_forward == 0 { 0 } else { num_forward };
    (out, remap)
}

/// Whether an op can join an elementwise fusion chain, and how.
fn fuse_step(op: &Op) -> Option<FusedStep> {
    match op {
        Op::Activation { kind } => Some(FusedStep::Act(*kind)),
        Op::AddScalar { s } => Some(FusedStep::AddScalar(*s)),
        Op::MulScalar { s } => Some(FusedStep::MulScalar(*s)),
        Op::Elemwise { op } => Some(FusedStep::Binary(*op)),
        _ => None,
    }
}

/// Fuse maximal straight-line chains of elementwise ops into
/// [`Op::FusedElemwise`] nodes.
///
/// A chain `x -> f1 -> f2 -> ... -> fk` fuses when every intermediate is
/// consumed exactly once (by the next op in the chain) and is not a graph
/// output, and the chain does not cross the forward/backward boundary.
/// Returns the rewritten graph and an entry remap for external bookkeeping
/// (e.g. gradient entries).
pub fn fuse_elementwise(graph: &Graph, protected: &[Entry]) -> (Graph, HashMap<Entry, Entry>) {
    let rc = graph.entry_refcounts(&[]);
    let mut protected_set: HashSet<Entry> = protected.iter().copied().collect();
    for e in &graph.outputs {
        protected_set.insert(*e);
    }

    // chain_next[n] = m when node m is the unique consumer of n's single
    // output and both are fusable in the same segment.
    let n_nodes = graph.nodes.len();
    let mut consumer: Vec<Option<NodeId>> = vec![None; n_nodes];
    let mut consumer_count: Vec<usize> = vec![0; n_nodes];
    for (id, node) in graph.nodes.iter().enumerate() {
        for e in &node.inputs {
            consumer_count[e.node] += 1;
            consumer[e.node] = Some(id);
        }
    }

    let segment = |id: NodeId| -> usize {
        if graph.num_forward == 0 || id < graph.num_forward {
            0
        } else {
            1
        }
    };

    let fusable = |id: NodeId| -> bool { fuse_step(&graph.nodes[id].op).is_some() };

    // A node continues the chain of its first input when:
    let continues = |id: NodeId| -> Option<NodeId> {
        if !fusable(id) {
            return None;
        }
        let prev = graph.nodes[id].inputs.first()?.node;
        if !fusable(prev) {
            return None;
        }
        let prev_entry = Entry::new(prev);
        if graph.nodes[id].inputs[0] != prev_entry {
            return None;
        }
        if rc.get(&prev_entry).copied().unwrap_or(0) != 1 {
            return None;
        }
        if consumer_count[prev] != 1 || consumer[prev] != Some(id) {
            return None;
        }
        if protected_set.contains(&prev_entry) {
            return None;
        }
        if segment(prev) != segment(id) {
            return None;
        }
        Some(prev)
    };

    // Identify chain heads: fusable nodes that do not continue another
    // fusable node, but are continued at least once.
    let mut chain_of: Vec<Option<usize>> = vec![None; n_nodes]; // node -> chain id
    let mut chains: Vec<Vec<NodeId>> = Vec::new();
    for id in 0..n_nodes {
        if continues(id).is_some() {
            continue; // not a head
        }
        if !fusable(id) {
            continue;
        }
        // walk forward while the next node continues this one
        let mut chain = vec![id];
        let mut cur = id;
        while let Some(next) = consumer[cur] {
            if continues(next) == Some(cur) {
                chain.push(next);
                cur = next;
            } else {
                break;
            }
        }
        if chain.len() >= 2 {
            let cid = chains.len();
            for &n in &chain {
                chain_of[n] = Some(cid);
            }
            chains.push(chain);
        }
    }

    // Rebuild the graph, replacing each chain with one fused node emitted
    // at the position of the chain's *last* member (all inputs available).
    let mut out = Graph::new();
    let mut entry_map: HashMap<Entry, Entry> = HashMap::new();
    let mut num_forward_new = 0usize;
    let map_entry = |m: &HashMap<Entry, Entry>, e: Entry| -> Entry {
        *m.get(&e).unwrap_or_else(|| panic!("unmapped entry {e:?}"))
    };
    for (id, node) in graph.nodes.iter().enumerate() {
        let emitted: Option<NodeId> = match chain_of[id] {
            Some(cid) => {
                let chain = &chains[cid];
                if *chain.last().unwrap() != id {
                    None // interior member: emitted with the tail
                } else {
                    // build the fused node
                    let head = chain[0];
                    let mut steps = Vec::with_capacity(chain.len());
                    let mut inputs =
                        vec![map_entry(&entry_map, graph.nodes[head].inputs[0])];
                    for &member in chain.iter() {
                        let step = fuse_step(&graph.nodes[member].op).expect("fusable");
                        if let FusedStep::Binary(_) = step {
                            // second operand joins the fused inputs (for the
                            // head its first input is already the chain input)
                            let extra = if member == head {
                                graph.nodes[member].inputs[1]
                            } else {
                                graph.nodes[member].inputs[1]
                            };
                            inputs.push(map_entry(&entry_map, extra));
                        }
                        steps.push(step);
                    }
                    let name = format!("fused_{}", graph.nodes[head].name);
                    let nid = out.nodes.len();
                    out.nodes.push(Node {
                        op: Op::FusedElemwise { steps },
                        name,
                        inputs,
                        control_deps: vec![],
                    });
                    Some(nid)
                }
            }
            None => {
                let inputs: Vec<Entry> =
                    node.inputs.iter().map(|e| map_entry(&entry_map, *e)).collect();
                let nid = out.nodes.len();
                out.nodes.push(Node {
                    op: node.op.clone(),
                    name: node.name.clone(),
                    inputs,
                    control_deps: vec![],
                });
                Some(nid)
            }
        };
        if let Some(nid) = emitted {
            for o in 0..graph.num_outputs_of(id) {
                entry_map.insert(Entry { node: id, out: o }, Entry { node: nid, out: o });
            }
        } else {
            // interior chain member: its single output maps to the fused
            // node once emitted — defer by mapping later; for simplicity,
            // map now to a placeholder resolved when the tail emits.
        }
        if id + 1 == graph.num_forward {
            num_forward_new = out.nodes.len();
        }
    }
    // Second pass: interior chain members map to their chain's fused node.
    for (cid, chain) in chains.iter().enumerate() {
        let tail = *chain.last().unwrap();
        let fused_entry = entry_map[&Entry::new(tail)];
        for &member in chain.iter() {
            if member != tail {
                entry_map.insert(Entry::new(member), fused_entry);
            }
        }
        let _ = cid;
    }
    out.outputs = graph.outputs.iter().map(|e| entry_map[e]).collect();
    out.num_forward = if graph.num_forward == 0 { 0 } else { num_forward_new };
    (out, entry_map)
}

/// The steps an op contributes when absorbed into a producer's epilogue
/// (`None` = not absorbable).  `FusedElemwise` nodes — produced by the
/// preceding [`fuse_elementwise`] pass — are absorbed wholesale.
fn epilogue_steps(op: &Op) -> Option<Vec<FusedStep>> {
    match op {
        Op::Activation { kind } => Some(vec![FusedStep::Act(*kind)]),
        Op::AddScalar { s } => Some(vec![FusedStep::AddScalar(*s)]),
        Op::MulScalar { s } => Some(vec![FusedStep::MulScalar(*s)]),
        Op::FusedElemwise { steps } => Some(steps.clone()),
        _ => None,
    }
}

/// Fold the single-consumer chain of elementwise ops following a
/// `FullyConnected` / `Convolution` node into the producer's `epilogue`
/// field, so the chain runs inside the producer kernel while each output
/// tile is cache-hot (the graph-compiler half of the epilogue-fusion
/// optimization; the kernel half is `ndarray::kernels::Epilogue`).
///
/// A chain `P -> f1 -> ... -> fk` folds when `P` is a forward-segment
/// FC/conv and every intermediate (including `P`'s own output) is
/// consumed exactly once, by the next op in the chain via its first
/// input, is not a graph output or `protected`, and does not cross the
/// forward/backward boundary.  Extra `Binary` operands join the fused
/// node's inputs after `(x, w, b)`, in step order.
///
/// Gradients are unaffected: only refcount-1 intermediates are
/// rewritten, and the existing activation backwards consume the
/// *post*-activation output — which becomes the fused node's output.
/// Returns the rewritten graph and an entry remap for external
/// bookkeeping (e.g. gradient entries).
pub fn fuse_epilogue(graph: &Graph, protected: &[Entry]) -> (Graph, HashMap<Entry, Entry>) {
    let rc = graph.entry_refcounts(&[]);
    let mut protected_set: HashSet<Entry> = protected.iter().copied().collect();
    for e in &graph.outputs {
        protected_set.insert(*e);
    }

    let n_nodes = graph.nodes.len();
    let mut consumer: Vec<Option<NodeId>> = vec![None; n_nodes];
    let mut consumer_count: Vec<usize> = vec![0; n_nodes];
    for (id, node) in graph.nodes.iter().enumerate() {
        for e in &node.inputs {
            consumer_count[e.node] += 1;
            consumer[e.node] = Some(id);
        }
    }

    let segment = |id: NodeId| -> usize {
        if graph.num_forward == 0 || id < graph.num_forward {
            0
        } else {
            1
        }
    };

    // Can `id`'s unique consumer absorb it?  The criteria mirror
    // fuse_elementwise: single use, consumed via input 0, unprotected,
    // same segment, absorbable op.
    let absorbed_by = |id: NodeId| -> Option<NodeId> {
        let e = Entry::new(id);
        if rc.get(&e).copied().unwrap_or(0) != 1 || protected_set.contains(&e) {
            return None;
        }
        let next = consumer[id]?;
        if consumer_count[id] != 1 {
            return None;
        }
        if graph.nodes[next].inputs.first() != Some(&e) {
            return None;
        }
        if epilogue_steps(&graph.nodes[next].op).is_none() {
            return None;
        }
        if segment(id) != segment(next) {
            return None;
        }
        Some(next)
    };

    // chains[cid] = [producer, member, ...]; producer is an FC/conv in
    // the forward segment with a (still) empty epilogue.
    let mut chain_of: Vec<Option<usize>> = vec![None; n_nodes];
    let mut chains: Vec<Vec<NodeId>> = Vec::new();
    for id in 0..n_nodes {
        let is_producer = matches!(
            graph.nodes[id].op,
            Op::FullyConnected { .. } | Op::Convolution { .. }
        ) && graph.nodes[id].op.epilogue().is_empty()
            && segment(id) == 0;
        if !is_producer {
            continue;
        }
        let mut chain = vec![id];
        let mut cur = id;
        while let Some(next) = absorbed_by(cur) {
            chain.push(next);
            cur = next;
        }
        if chain.len() >= 2 {
            let cid = chains.len();
            for &n in &chain {
                chain_of[n] = Some(cid);
            }
            chains.push(chain);
        }
    }

    // Rebuild, emitting each fused producer at its chain *tail*'s
    // position (every extra operand is produced before the tail).
    let mut out = Graph::new();
    let mut entry_map: HashMap<Entry, Entry> = HashMap::new();
    let mut num_forward_new = 0usize;
    let map_entry = |m: &HashMap<Entry, Entry>, e: Entry| -> Entry {
        *m.get(&e).unwrap_or_else(|| panic!("unmapped entry {e:?}"))
    };
    for (id, node) in graph.nodes.iter().enumerate() {
        let emitted: Option<NodeId> = match chain_of[id] {
            Some(cid) => {
                let chain = &chains[cid];
                if *chain.last().unwrap() != id {
                    None // producer / interior member: emitted with the tail
                } else {
                    let pnode = &graph.nodes[chain[0]];
                    let mut steps: Vec<FusedStep> = Vec::new();
                    let mut inputs: Vec<Entry> =
                        pnode.inputs.iter().map(|e| map_entry(&entry_map, *e)).collect();
                    for &member in &chain[1..] {
                        let msteps =
                            epilogue_steps(&graph.nodes[member].op).expect("absorbable");
                        let mut extra = 1usize;
                        for st in &msteps {
                            if let FusedStep::Binary(_) = st {
                                inputs.push(map_entry(
                                    &entry_map,
                                    graph.nodes[member].inputs[extra],
                                ));
                                extra += 1;
                            }
                        }
                        steps.extend(msteps);
                    }
                    let op = match &pnode.op {
                        Op::FullyConnected { num_hidden, .. } => {
                            Op::FullyConnected { num_hidden: *num_hidden, epilogue: steps }
                        }
                        Op::Convolution { num_filter, kernel, stride, pad, .. } => Op::Convolution {
                            num_filter: *num_filter,
                            kernel: *kernel,
                            stride: *stride,
                            pad: *pad,
                            epilogue: steps,
                        },
                        other => unreachable!("non-epilogue producer {:?}", other.type_name()),
                    };
                    let nid = out.nodes.len();
                    out.nodes.push(Node {
                        op,
                        name: format!("{}_ep", pnode.name),
                        inputs,
                        control_deps: vec![],
                    });
                    Some(nid)
                }
            }
            None => {
                let inputs: Vec<Entry> =
                    node.inputs.iter().map(|e| map_entry(&entry_map, *e)).collect();
                let nid = out.nodes.len();
                out.nodes.push(Node {
                    op: node.op.clone(),
                    name: node.name.clone(),
                    inputs,
                    control_deps: vec![],
                });
                Some(nid)
            }
        };
        if let Some(nid) = emitted {
            for o in 0..graph.num_outputs_of(id) {
                entry_map.insert(Entry { node: id, out: o }, Entry { node: nid, out: o });
            }
        }
        if id + 1 == graph.num_forward {
            num_forward_new = out.nodes.len();
        }
    }
    // Producer and interior members map to the fused node's output.
    for chain in &chains {
        let tail = *chain.last().unwrap();
        let fused_entry = entry_map[&Entry::new(tail)];
        for &member in chain.iter() {
            if member != tail {
                entry_map.insert(Entry::new(member), fused_entry);
            }
        }
    }
    out.outputs = graph.outputs.iter().map(|e| entry_map[e]).collect();
    out.num_forward = if graph.num_forward == 0 { 0 } else { num_forward_new };
    (out, entry_map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::build_backward;
    use crate::graph::infer_shapes;
    use crate::graph::tests::mlp_graph;
    use crate::ndarray::kernels::EwBinary;

    #[test]
    fn prune_drops_backward_for_prediction() {
        let (mut g, _vs) = mlp_graph(8);
        let params: Vec<_> = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"]
            .iter()
            .map(|n| g.find_variable(n).unwrap())
            .collect();
        let full_fwd_outputs = g.outputs.clone();
        build_backward(&mut g, &params).unwrap();
        let total = g.nodes.len();
        let (pruned, _) = prune(&g, &full_fwd_outputs);
        assert!(pruned.nodes.len() < total, "{} !< {total}", pruned.nodes.len());
        pruned.validate().unwrap();
        // label var still present (softmax head consumes it); all backward
        // nodes gone
        assert!(pruned
            .nodes
            .iter()
            .all(|n| !n.name.contains("backward")));
    }

    #[test]
    fn prune_to_internal_layer_extracts_features() {
        // Feature extraction: request relu1, drop fc2/softmax (paper:
        // "the last layers can be skipped").
        let (g, _vs) = mlp_graph(8);
        let relu = g.nodes.iter().position(|n| n.name == "relu1").unwrap();
        let (pruned, _) = prune(&g, &[Entry::new(relu)]);
        assert!(pruned.nodes.iter().all(|n| n.name != "fc2" && n.name != "softmax"));
        assert!(pruned.nodes.iter().any(|n| n.name == "relu1"));
    }

    #[test]
    fn fuse_a_times_b_plus_one() {
        // The paper's example: a*b + 1 becomes a single call.
        let mut g = Graph::new();
        let a = g.add_variable("a");
        let b = g.add_variable("b");
        let mul = g.add_node(
            Op::Elemwise { op: EwBinary::Mul },
            "mul",
            vec![Entry::new(a), Entry::new(b)],
        );
        let add1 = g.add_node(Op::AddScalar { s: 1.0 }, "plus1", vec![Entry::new(mul)]);
        g.outputs = vec![Entry::new(add1)];
        let (fused, map) = fuse_elementwise(&g, &[]);
        fused.validate().unwrap();
        let fused_nodes: Vec<_> = fused
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::FusedElemwise { .. }))
            .collect();
        assert_eq!(fused_nodes.len(), 1);
        if let Op::FusedElemwise { steps } = &fused_nodes[0].op {
            assert_eq!(
                steps,
                &vec![FusedStep::Binary(EwBinary::Mul), FusedStep::AddScalar(1.0)]
            );
        }
        // variables survive; total nodes = 2 vars + 1 fused
        assert_eq!(fused.nodes.len(), 3);
        assert!(map.contains_key(&Entry::new(add1)));
        // shape inference works on the fused graph
        let mut vs = std::collections::HashMap::new();
        vs.insert("a".into(), vec![4, 4]);
        vs.insert("b".into(), vec![4, 4]);
        let shapes = infer_shapes(&fused, &vs).unwrap();
        let out = fused.outputs[0];
        assert_eq!(shapes[out.node][out.out], vec![4, 4]);
    }

    #[test]
    fn fuse_respects_fanout() {
        // mul feeds two consumers -> must NOT fuse into either.
        let mut g = Graph::new();
        let a = g.add_variable("a");
        let mul = g.add_node(
            Op::Elemwise { op: EwBinary::Mul },
            "mul",
            vec![Entry::new(a), Entry::new(a)],
        );
        let x = g.add_node(Op::AddScalar { s: 1.0 }, "x", vec![Entry::new(mul)]);
        let y = g.add_node(Op::MulScalar { s: 2.0 }, "y", vec![Entry::new(mul)]);
        g.outputs = vec![Entry::new(x), Entry::new(y)];
        let (fused, _) = fuse_elementwise(&g, &[]);
        assert!(
            fused.nodes.iter().all(|n| !matches!(n.op, Op::FusedElemwise { .. })),
            "fan-out chain must not fuse"
        );
    }

    #[test]
    fn fuse_does_not_swallow_graph_outputs() {
        let mut g = Graph::new();
        let a = g.add_variable("a");
        let p1 = g.add_node(Op::AddScalar { s: 1.0 }, "p1", vec![Entry::new(a)]);
        let p2 = g.add_node(Op::AddScalar { s: 2.0 }, "p2", vec![Entry::new(p1)]);
        // p1 is itself an output -> cannot be fused away
        g.outputs = vec![Entry::new(p1), Entry::new(p2)];
        let (fused, _) = fuse_elementwise(&g, &[]);
        assert!(fused.nodes.iter().all(|n| !matches!(n.op, Op::FusedElemwise { .. })));
    }

    #[test]
    fn fused_graph_preserves_num_forward() {
        let (mut g, _vs) = mlp_graph(8);
        let params: Vec<_> = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"]
            .iter()
            .map(|n| g.find_variable(n).unwrap())
            .collect();
        build_backward(&mut g, &params).unwrap();
        let (fused, _) = fuse_elementwise(&g, &[]);
        fused.validate().unwrap();
        assert!(fused.num_forward > 0);
        assert!(fused.num_forward <= fused.nodes.len());
    }

    use crate::ndarray::kernels::ActKind;

    #[test]
    fn fc_relu_folds_into_epilogue() {
        // The mlp graph's fc1 -> relu1 chain must fold; fc2 feeds the
        // softmax head (not absorbable) and stays plain.
        let (g, vs) = mlp_graph(8);
        let (fused, map) = fuse_epilogue(&g, &[]);
        fused.validate().unwrap();
        assert_eq!(fused.nodes.len(), g.nodes.len() - 1);
        let fc1 = fused.nodes.iter().find(|n| n.name == "fc1_ep").expect("fused fc1");
        assert_eq!(fc1.op.epilogue(), &[FusedStep::Act(ActKind::Relu)]);
        assert_eq!(fc1.op.label(), "FullyConnected+relu");
        assert!(fused.nodes.iter().all(|n| !matches!(n.op, Op::Activation { .. })));
        let fc2 = fused.nodes.iter().find(|n| n.name == "fc2").expect("plain fc2");
        assert!(fc2.op.epilogue().is_empty());
        // shape inference still works and the old relu entry remaps to
        // the fused node's output
        let shapes = infer_shapes(&fused, &vs).unwrap();
        let out = fused.outputs[0];
        assert_eq!(shapes[out.node][out.out], vec![8, 10]);
        let relu_old = g.nodes.iter().position(|n| n.name == "relu1").unwrap();
        let fc1_new = fused.nodes.iter().position(|n| n.name == "fc1_ep").unwrap();
        assert_eq!(map[&Entry::new(relu_old)], Entry::new(fc1_new));
    }

    #[test]
    fn epilogue_absorbs_fused_elemwise_with_binary_operand() {
        // fc -> (y * res) + 1 : fuse_elementwise first collapses the
        // chain into FusedElemwise, then fuse_epilogue folds it into the
        // FC with `res` appended as an extra input.
        let mut g = Graph::new();
        let data = g.add_variable("data");
        let w = g.add_variable("w");
        let b = g.add_variable("b");
        let res = g.add_variable("res");
        let fc = g.add_node(
            Op::FullyConnected { num_hidden: 4, epilogue: vec![] },
            "fc",
            vec![Entry::new(data), Entry::new(w), Entry::new(b)],
        );
        let mul = g.add_node(
            Op::Elemwise { op: EwBinary::Mul },
            "mul",
            vec![Entry::new(fc), Entry::new(res)],
        );
        let add1 = g.add_node(Op::AddScalar { s: 1.0 }, "plus1", vec![Entry::new(mul)]);
        g.outputs = vec![Entry::new(add1)];
        let (ew, _) = fuse_elementwise(&g, &[]);
        let (fused, _) = fuse_epilogue(&ew, &[]);
        fused.validate().unwrap();
        let fc = fused
            .nodes
            .iter()
            .find(|n| matches!(n.op, Op::FullyConnected { .. }))
            .expect("fc survives");
        assert_eq!(
            fc.op.epilogue(),
            &[FusedStep::Binary(EwBinary::Mul), FusedStep::AddScalar(1.0)]
        );
        assert_eq!(fc.inputs.len(), 4, "extra binary operand appended");
        // 4 variables + 1 fused node
        assert_eq!(fused.nodes.len(), 5);
        let mut vs = std::collections::HashMap::new();
        vs.insert("data".into(), vec![2, 6]);
        vs.insert("w".into(), vec![4, 6]);
        vs.insert("b".into(), vec![4]);
        vs.insert("res".into(), vec![2, 4]);
        infer_shapes(&fused, &vs).unwrap();
    }

    #[test]
    fn epilogue_respects_fanout_outputs_and_protection() {
        // fan-out: fc output consumed twice -> no fusion
        let (mut g, _) = mlp_graph(8);
        let fc1 = g.nodes.iter().position(|n| n.name == "fc1").unwrap();
        let tap = g.add_node(Op::Identity, "tap", vec![Entry::new(fc1)]);
        g.outputs.push(Entry::new(tap));
        g.num_forward = g.nodes.len();
        let (fused, _) = fuse_epilogue(&g, &[]);
        assert!(fused.nodes.iter().all(|n| n.op.epilogue().is_empty()), "fan-out fused");

        // protection: the producer entry listed as protected -> no fusion
        let (g2, _) = mlp_graph(8);
        let fc1 = g2.nodes.iter().position(|n| n.name == "fc1").unwrap();
        let (fused2, _) = fuse_epilogue(&g2, &[Entry::new(fc1)]);
        assert!(fused2.nodes.iter().all(|n| n.op.epilogue().is_empty()), "protected fused");

        // graph output: a bare fc head must not be swallowed
        let (g3, _) = mlp_graph(8);
        let relu = g3.nodes.iter().position(|n| n.name == "relu1").unwrap();
        let (pruned, _) = prune(&g3, &[Entry::new(relu)]);
        let (fused3, _) = fuse_epilogue(&pruned, &[]);
        // relu1 is the output -> still fusable (fc1 itself is interior)
        assert!(fused3.nodes.iter().any(|n| !n.op.epilogue().is_empty()));
        let (pruned_fc, _) = prune(&g3, &[Entry::new(fc1)]);
        let (fused4, _) = fuse_epilogue(&pruned_fc, &[]);
        assert!(fused4.nodes.iter().all(|n| n.op.epilogue().is_empty()));
    }

    #[test]
    fn epilogue_fusion_applies_in_training_graphs() {
        // After autodiff, fc1's pre-activation output still has refcount
        // 1 (FullyConnectedBackward consumes (dy, x, w); the activation
        // backward consumes the *post*-activation output), so the chain
        // folds and the backward half is untouched.
        let (mut g, _vs) = mlp_graph(8);
        let params: Vec<_> = ["fc1_weight", "fc1_bias", "fc2_weight", "fc2_bias"]
            .iter()
            .map(|n| g.find_variable(n).unwrap())
            .collect();
        build_backward(&mut g, &params).unwrap();
        let bwd_nodes = g.nodes.len() - g.num_forward;
        let (fused, map) = fuse_epilogue(&g, &[]);
        fused.validate().unwrap();
        assert!(fused.nodes.iter().any(|n| !n.op.epilogue().is_empty()), "no fusion");
        assert_eq!(fused.nodes.len() - fused.num_forward, bwd_nodes, "backward rewritten");
        // every original grad-relevant entry remains mapped
        for e in &g.outputs {
            assert!(map.contains_key(e));
        }
    }
}

//! `NDArray` — imperative tensor computation with lazy evaluation
//! (paper §2.2).
//!
//! Every `NDArray` owns a storage buffer registered with the dependency
//! engine under a unique tag.  Methods like [`NDArray::add`] do **not**
//! compute anything on the calling thread: they push an operation reading
//! the operands' tags and writing the result's tag, and return
//! immediately.  Reading data out ([`NDArray::to_vec`]) waits for the tag.
//!
//! Because symbolic executors push their node operations onto the same
//! engine with the same tags, imperative updates interleave correctly with
//! graph execution — `net.forward_backward(); net.w -= eta * net.g` is
//! scheduled as one dataflow, the paper's headline flexibility claim.

pub mod kernels;
pub mod ops;
pub mod pool;

use std::cell::UnsafeCell;
use std::sync::Arc;

use crate::engine::{default_engine, EngineRef, VarHandle};
use crate::util::Rng;

/// Raw storage behind an `NDArray`.
///
/// Interior mutability is sound because every access goes through the
/// dependency engine, which guarantees a writer is exclusive and readers
/// never overlap a writer (the same argument MXNet makes for its NDArray).
///
/// Buffers are drawn from the process-wide [storage pool](pool) and
/// recycled on drop, so the steady-state hot loop (whose buffer sizes
/// recur every step) allocates nothing after warmup.
pub struct Storage {
    data: UnsafeCell<Box<[f32]>>,
    /// Whether the buffer goes back to the pool on drop (set when the
    /// pool was enabled at creation; `from_vec` buffers are caller data
    /// and are freed normally).
    pooled: bool,
}

// SAFETY: access discipline enforced by the engine (exclusive writes).
unsafe impl Sync for Storage {}
unsafe impl Send for Storage {}

impl Drop for Storage {
    fn drop(&mut self) {
        if self.pooled {
            let buf = std::mem::take(self.data.get_mut());
            pool::global().release(buf);
        }
    }
}

impl Storage {
    fn new(len: usize, fill: f32) -> Arc<Self> {
        let p = pool::global();
        Arc::new(Storage {
            data: UnsafeCell::new(p.acquire_filled(len, fill)),
            pooled: p.enabled(),
        })
    }

    /// Pool-backed buffer whose contents are unspecified until first
    /// written (a recycled buffer keeps its previous owner's values; a
    /// fresh one is zeroed — never uninitialized memory).
    fn new_uninit(len: usize) -> Arc<Self> {
        let p = pool::global();
        Arc::new(Storage {
            data: UnsafeCell::new(p.acquire_uninit(len)),
            pooled: p.enabled(),
        })
    }

    fn from_vec(v: Vec<f32>) -> Arc<Self> {
        Arc::new(Storage { data: UnsafeCell::new(v.into_boxed_slice()), pooled: false })
    }

    /// Read access. Caller must hold a read grant from the engine.
    ///
    /// # Safety
    /// Must only be called from an engine op that listed this storage's
    /// var as a read (or write) dependency, or after `wait_for_var`.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self) -> &[f32] {
        &*self.data.get()
    }

    /// Write access. Caller must hold the write grant from the engine.
    ///
    /// # Safety
    /// Must only be called from an engine op that listed this storage's
    /// var as a write dependency.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self) -> &mut [f32] {
        &mut *self.data.get()
    }

    /// Buffer length.
    pub fn len(&self) -> usize {
        unsafe { (&raw const *self.data.get()).as_ref().unwrap().len() }
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

struct Inner {
    shape: Vec<usize>,
    storage: Arc<Storage>,
    var: VarHandle,
    engine: EngineRef,
    /// For reshape views: keeps the owning array (and thus its engine var)
    /// alive; a view never deletes the var itself.
    base: Option<Arc<Inner>>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        if self.base.is_none() {
            self.engine.delete_var(self.var);
        }
    }
}

/// An n-dimensional f32 array with engine-scheduled lazy evaluation.
#[derive(Clone)]
pub struct NDArray {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for NDArray {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NDArray(shape={:?}, var={})", self.shape(), self.var().id())
    }
}

impl NDArray {
    // ---------------------------------------------------------------
    // constructors
    // ---------------------------------------------------------------

    fn alloc(shape: &[usize], fill: f32, engine: EngineRef) -> Self {
        let size: usize = shape.iter().product();
        let var = engine.new_var();
        NDArray {
            inner: Arc::new(Inner {
                shape: shape.to_vec(),
                storage: Storage::new(size, fill),
                var,
                engine,
                base: None,
            }),
        }
    }

    /// Array whose contents are unspecified until first written, drawn
    /// from the [storage pool](pool) with **no zero-fill on a pool hit**.
    ///
    /// For buffers whose first use fully overwrites them — executor
    /// temporaries, RNG fills, serve scatter targets, op results.  The
    /// contents are never uninitialized *memory* (a miss allocates
    /// zeroed; a hit carries the previous owner's values), so reading
    /// before writing is unspecified but sound.
    pub fn alloc_uninit(shape: &[usize]) -> Self {
        Self::alloc_uninit_on(shape, default_engine())
    }

    /// [`NDArray::alloc_uninit`] on a specific engine.
    pub fn alloc_uninit_on(shape: &[usize], engine: EngineRef) -> Self {
        let size: usize = shape.iter().product();
        let var = engine.new_var();
        NDArray {
            inner: Arc::new(Inner {
                shape: shape.to_vec(),
                storage: Storage::new_uninit(size),
                var,
                engine,
                base: None,
            }),
        }
    }

    /// Zero-filled array on the default engine.
    pub fn zeros(shape: &[usize]) -> Self {
        Self::zeros_on(shape, default_engine())
    }

    /// Zero-filled array on a specific engine.
    pub fn zeros_on(shape: &[usize], engine: EngineRef) -> Self {
        Self::alloc(shape, 0.0, engine)
    }

    /// One-filled array.
    pub fn ones(shape: &[usize]) -> Self {
        Self::alloc(shape, 1.0, default_engine())
    }

    /// Constant-filled array.
    pub fn full(shape: &[usize], value: f32) -> Self {
        Self::alloc(shape, value, default_engine())
    }

    /// Array from explicit data (len must equal product of dims).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        Self::from_vec_on(shape, data, default_engine())
    }

    /// Array from explicit data on a specific engine.
    pub fn from_vec_on(shape: &[usize], data: Vec<f32>, engine: EngineRef) -> Self {
        let size: usize = shape.iter().product();
        assert_eq!(size, data.len(), "shape {shape:?} vs data len {}", data.len());
        let var = engine.new_var();
        NDArray {
            inner: Arc::new(Inner {
                shape: shape.to_vec(),
                storage: Storage::from_vec(data),
                var,
                engine,
                base: None,
            }),
        }
    }

    /// Gaussian-initialized array (engine-scheduled fill).
    pub fn randn(shape: &[usize], mean: f32, std: f32, seed: u64) -> Self {
        Self::randn_on(shape, mean, std, seed, default_engine())
    }

    /// Gaussian-initialized array on a specific engine.
    pub fn randn_on(shape: &[usize], mean: f32, std: f32, seed: u64, engine: EngineRef) -> Self {
        let out = Self::alloc_uninit_on(shape, engine);
        let storage = out.storage();
        out.engine().push(
            "randn",
            vec![],
            vec![out.var()],
            Box::new(move || {
                let mut rng = Rng::seed_from_u64(seed);
                let buf = unsafe { storage.slice_mut() };
                for v in buf.iter_mut() {
                    *v = rng.normal_with(mean, std);
                }
            }),
        );
        out
    }

    /// Uniform-initialized array in `[lo, hi)`.
    pub fn uniform(shape: &[usize], lo: f32, hi: f32, seed: u64) -> Self {
        let out = Self::alloc_uninit(shape);
        let storage = out.storage();
        out.engine().push(
            "uniform",
            vec![],
            vec![out.var()],
            Box::new(move || {
                let mut rng = Rng::seed_from_u64(seed);
                let buf = unsafe { storage.slice_mut() };
                for v in buf.iter_mut() {
                    *v = rng.uniform(lo, hi);
                }
            }),
        );
        out
    }

    // ---------------------------------------------------------------
    // accessors
    // ---------------------------------------------------------------

    /// Shape dims.
    pub fn shape(&self) -> &[usize] {
        &self.inner.shape
    }

    /// Total element count.
    pub fn size(&self) -> usize {
        self.inner.shape.iter().product()
    }

    /// Engine tag for this array's storage.
    pub fn var(&self) -> VarHandle {
        self.inner.var
    }

    /// The engine this array is registered with.
    pub fn engine(&self) -> EngineRef {
        Arc::clone(&self.inner.engine)
    }

    /// Shared storage handle (for pushing custom engine ops).
    pub fn storage(&self) -> Arc<Storage> {
        Arc::clone(&self.inner.storage)
    }

    /// Block until all pending writes to this array have completed.
    pub fn wait_to_read(&self) {
        self.inner.engine.wait_for_var(self.inner.var);
    }

    /// Synchronously copy the contents out.
    pub fn to_vec(&self) -> Vec<f32> {
        self.wait_to_read();
        unsafe { self.inner.storage.slice().to_vec() }
    }

    /// Synchronously read a single element (flattened index).
    pub fn at(&self, idx: usize) -> f32 {
        self.wait_to_read();
        unsafe { self.inner.storage.slice()[idx] }
    }

    /// Synchronously overwrite contents from a slice.
    pub fn copy_from_slice_sync(&self, data: &[f32]) {
        assert_eq!(data.len(), self.size());
        let storage = self.storage();
        let data = data.to_vec();
        self.engine().push(
            "copy_from",
            vec![],
            vec![self.var()],
            Box::new(move || {
                unsafe { storage.slice_mut() }.copy_from_slice(&data);
            }),
        );
        self.wait_to_read();
    }

    /// View this array's storage under a (possibly smaller) shape.
    ///
    /// Shares storage **and** engine tag, so dependency tracking covers
    /// the alias.  Used by the executor to carve per-entry views out of
    /// co-shared plan storage blocks (the view may use a prefix of the
    /// block).
    pub fn alias(&self, shape: &[usize]) -> NDArray {
        let size: usize = shape.iter().product();
        assert!(
            size <= self.inner.storage.len(),
            "alias {shape:?} exceeds storage of {} elems",
            self.inner.storage.len()
        );
        NDArray {
            inner: Arc::new(Inner {
                shape: shape.to_vec(),
                storage: self.storage(),
                var: self.inner.var,
                engine: self.engine(),
                base: Some(Arc::clone(&self.inner)),
            }),
        }
    }

    /// Reinterpret with a new shape of equal size (shares storage and tag).
    pub fn reshape(&self, shape: &[usize]) -> NDArray {
        let size: usize = shape.iter().product();
        assert_eq!(size, self.size(), "reshape {:?} -> {shape:?}", self.shape());
        NDArray {
            inner: Arc::new(Inner {
                shape: shape.to_vec(),
                storage: self.storage(),
                // Sharing the var keeps the dependency story exact: readers
                // of the view are ordered against writes through the base
                // and vice versa.  `base` keeps the owner alive so the var
                // is deleted exactly once, by the owner.
                var: self.inner.var,
                engine: self.engine(),
                base: Some(Arc::clone(&self.inner)),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_to_vec() {
        let z = NDArray::zeros(&[2, 3]);
        assert_eq!(z.to_vec(), vec![0.0; 6]);
        let o = NDArray::ones(&[4]);
        assert_eq!(o.to_vec(), vec![1.0; 4]);
        let f = NDArray::full(&[2, 2], 7.5);
        assert_eq!(f.to_vec(), vec![7.5; 4]);
        let v = NDArray::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(v.at(3), 4.0);
    }

    #[test]
    fn randn_reproducible() {
        let a = NDArray::randn(&[100], 0.0, 1.0, 42);
        let b = NDArray::randn(&[100], 0.0, 1.0, 42);
        assert_eq!(a.to_vec(), b.to_vec());
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_shape_mismatch_panics() {
        let _ = NDArray::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn reshape_shares_data() {
        let a = NDArray::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let b = a.reshape(&[3, 2]);
        assert_eq!(b.shape(), &[3, 2]);
        assert_eq!(b.to_vec(), a.to_vec());
    }

    #[test]
    fn copy_from_slice_roundtrip() {
        let a = NDArray::zeros(&[3]);
        a.copy_from_slice_sync(&[1.0, 2.0, 3.0]);
        assert_eq!(a.to_vec(), vec![1.0, 2.0, 3.0]);
    }
}

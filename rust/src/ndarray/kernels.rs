//! Raw f32 math kernels on slices.
//!
//! These are the CPU "big operations" (paper §3.1: *"we manually
//! implemented well-optimized big operations, such as a layer in neural
//! network"*).  Both the imperative [`NDArray`](super::NDArray) methods and
//! the graph executor's native operator backend dispatch here, so the two
//! programming paradigms share one set of kernels — exactly the unified-
//! backend story of the paper.
//!
//! Layout conventions: matrices are row-major `[rows, cols]`; images are
//! NCHW.  All kernels are single-threaded; parallelism comes from the
//! dependency engine scheduling independent kernels concurrently.

use std::sync::atomic::{AtomicBool, Ordering};

/// When set, the GEMM family runs a deliberately *unoptimized* inner loop
/// (j-i-p order, strided, not vectorizable) — the stand-in for a
/// previous-generation kernel library (the paper's Figure 6 attributes
/// TensorFlow's 2x gap to CUDNN v2 vs v3).  See `cargo bench --bench
/// fig6_convnet`, mode `tf-old`.
static REFERENCE_MODE: AtomicBool = AtomicBool::new(false);

/// Switch the GEMM family between the optimized and the reference (slow)
/// implementations.  Affects the whole process; benches only.
pub fn set_reference_kernels(on: bool) {
    REFERENCE_MODE.store(on, Ordering::SeqCst);
}

/// Whether reference (slow) kernels are active.
pub fn reference_kernels() -> bool {
    REFERENCE_MODE.load(Ordering::SeqCst)
}

/// Naive j-i-p GEMM used in reference mode: column-at-a-time with strided
/// b access — roughly the memory-access pattern cost of an old kernel
/// generation.  `ta`/`tb` transpose a/b.
#[inline(never)]
fn gemm_reference(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    beta: f32,
    ta: bool,
    tb: bool,
) {
    let ai = |i: usize, p: usize| if ta { a[p * m + i] } else { a[i * k + p] };
    let bi = |p: usize, j: usize| if tb { b[j * k + p] } else { b[p * n + j] };
    for j in 0..n {
        for i in 0..m {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += ai(i, p) * bi(p, j);
            }
            let dst = &mut c[i * n + j];
            *dst = if beta == 0.0 { acc } else { *dst * beta + acc };
        }
    }
}

/// `c = a @ b` where a is `[m,k]`, b is `[k,n]`, c is `[m,n]`.
/// `beta == 0.0` overwrites c, `beta == 1.0` accumulates.
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, beta: f32) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if reference_kernels() {
        return gemm_reference(a, b, c, m, k, n, beta, false, false);
    }
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
    // i-k-j loop order: the inner j-loop is a saxpy over contiguous rows of
    // b and c, which LLVM auto-vectorizes.
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
}

/// Vectorizable dot product: 8 independent accumulator lanes so LLVM can
/// keep SIMD FMAs in flight without a loop-carried dependence.
#[inline]
fn vdot(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let x = &a[c * 8..c * 8 + 8];
        let y = &b[c * 8..c * 8 + 8];
        for l in 0..8 {
            lanes[l] += x[l] * y[l];
        }
    }
    let mut acc = lanes.iter().sum::<f32>();
    for p in chunks * 8..a.len() {
        acc += a[p] * b[p];
    }
    acc
}

/// `c = a @ b^T` where a is `[m,k]`, b is `[n,k]`, c is `[m,n]`.
///
/// This is the FullyConnected-forward shape (weights stored `[out, in]`),
/// i.e. the hottest kernel in training; both operands are traversed
/// contiguously and the inner dot is lane-parallel (see §Perf).
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, beta: f32) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if reference_kernels() {
        return gemm_reference(a, b, c, m, k, n, beta, false, true);
    }
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let acc = vdot(arow, brow);
            let dst = &mut c[i * n + j];
            *dst = if beta == 0.0 { acc } else { *dst * beta + acc };
        }
    }
}

/// `c = a^T @ b` where a is `[k,m]`, b is `[k,n]`, c is `[m,n]`.
pub fn gemm_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, beta: f32) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if reference_kernels() {
        return gemm_reference(a, b, c, m, k, n, beta, true, false);
    }
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let aip = arow[i];
            if aip == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = alpha * x + beta * y` (general scaled update).
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = alpha * xi + beta * *yi;
    }
}

/// Elementwise binary op.
pub fn ew_binary(op: EwBinary, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    match op {
        EwBinary::Add => {
            for i in 0..a.len() {
                out[i] = a[i] + b[i];
            }
        }
        EwBinary::Sub => {
            for i in 0..a.len() {
                out[i] = a[i] - b[i];
            }
        }
        EwBinary::Mul => {
            for i in 0..a.len() {
                out[i] = a[i] * b[i];
            }
        }
        EwBinary::Div => {
            for i in 0..a.len() {
                out[i] = a[i] / b[i];
            }
        }
    }
}

/// Elementwise binary operator selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwBinary {
    /// `a + b`
    Add,
    /// `a - b`
    Sub,
    /// `a * b`
    Mul,
    /// `a / b`
    Div,
}

/// Activation function selector (paper's `Activation(act_type=...)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    /// max(0, x)
    Relu,
    /// tanh(x)
    Tanh,
    /// 1/(1+exp(-x))
    Sigmoid,
}

/// Forward activation.
pub fn act_forward(kind: ActKind, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match kind {
        ActKind::Relu => {
            for i in 0..x.len() {
                y[i] = x[i].max(0.0);
            }
        }
        ActKind::Tanh => {
            for i in 0..x.len() {
                y[i] = x[i].tanh();
            }
        }
        ActKind::Sigmoid => {
            for i in 0..x.len() {
                y[i] = 1.0 / (1.0 + (-x[i]).exp());
            }
        }
    }
}

/// Backward activation: `dx = dy * f'(x)` computed from the *output* `y`
/// (all three supported activations allow this, which lets the forward
/// input be freed / reused inplace — important for the memory planner).
pub fn act_backward(kind: ActKind, dy: &[f32], y: &[f32], dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), y.len());
    debug_assert_eq!(dy.len(), dx.len());
    match kind {
        ActKind::Relu => {
            for i in 0..dy.len() {
                dx[i] = if y[i] > 0.0 { dy[i] } else { 0.0 };
            }
        }
        ActKind::Tanh => {
            for i in 0..dy.len() {
                dx[i] = dy[i] * (1.0 - y[i] * y[i]);
            }
        }
        ActKind::Sigmoid => {
            for i in 0..dy.len() {
                dx[i] = dy[i] * y[i] * (1.0 - y[i]);
            }
        }
    }
}

/// Broadcast-add a bias vector of length `n` to each row of `[m,n]`.
pub fn bias_add(x: &mut [f32], bias: &[f32], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    for i in 0..m {
        let row = &mut x[i * n..(i + 1) * n];
        for j in 0..n {
            row[j] += bias[j];
        }
    }
}

/// Gradient of bias: column sums of `[m,n]` into `dbias[n]`.
pub fn bias_grad(dy: &[f32], dbias: &mut [f32], m: usize, n: usize, beta: f32) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dbias.len(), n);
    if beta == 0.0 {
        dbias.fill(0.0);
    }
    for i in 0..m {
        let row = &dy[i * n..(i + 1) * n];
        for j in 0..n {
            dbias[j] += row[j];
        }
    }
}

/// Row-wise softmax over `[m,n]`.
pub fn softmax_rows(x: &[f32], y: &mut [f32], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    debug_assert_eq!(y.len(), m * n);
    for i in 0..m {
        let xr = &x[i * n..(i + 1) * n];
        let yr = &mut y[i * n..(i + 1) * n];
        let mx = xr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for j in 0..n {
            let e = (xr[j] - mx).exp();
            yr[j] = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for v in yr.iter_mut() {
            *v *= inv;
        }
    }
}

/// Mean cross-entropy loss given row-softmax probabilities and integer
/// labels; returns the scalar loss.
pub fn xent_loss(probs: &[f32], labels: &[f32], m: usize, n: usize) -> f32 {
    let mut loss = 0.0;
    for i in 0..m {
        let t = labels[i] as usize;
        debug_assert!(t < n);
        loss -= probs[i * n + t].max(1e-12).ln();
    }
    loss / m as f32
}

/// Gradient of softmax + cross-entropy w.r.t. logits: `(p - onehot)/m`.
pub fn softmax_xent_backward(probs: &[f32], labels: &[f32], dx: &mut [f32], m: usize, n: usize) {
    debug_assert_eq!(probs.len(), m * n);
    debug_assert_eq!(dx.len(), m * n);
    let scale = 1.0 / m as f32;
    for i in 0..m {
        let t = labels[i] as usize;
        for j in 0..n {
            let p = probs[i * n + j];
            dx[i * n + j] = scale * (p - if j == t { 1.0 } else { 0.0 });
        }
    }
}

/// Convolution geometry helper: output spatial size.
#[inline]
pub fn conv_out(size: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    (size + 2 * pad - kernel) / stride + 1
}

/// im2col for NCHW input, one image: input `[c, h, w]` -> columns
/// `[c*kh*kw, oh*ow]`.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    img: &[f32],
    col: &mut [f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, kh, stride, pad);
    let ow = conv_out(w, kw, stride, pad);
    debug_assert_eq!(img.len(), c * h * w);
    debug_assert_eq!(col.len(), c * kh * kw * oh * ow);
    let mut row = 0usize;
    for ch in 0..c {
        let plane = &img[ch * h * w..(ch + 1) * h * w];
        for ky in 0..kh {
            for kx in 0..kw {
                let dst = &mut col[row * oh * ow..(row + 1) * oh * ow];
                let mut idx = 0usize;
                for oy in 0..oh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        dst[idx..idx + ow].fill(0.0);
                        idx += ow;
                        continue;
                    }
                    let src_row = &plane[iy as usize * w..(iy as usize + 1) * w];
                    for ox in 0..ow {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        dst[idx] = if ix < 0 || ix >= w as isize {
                            0.0
                        } else {
                            src_row[ix as usize]
                        };
                        idx += 1;
                    }
                }
                row += 1;
            }
        }
    }
}

/// col2im: scatter-add columns `[c*kh*kw, oh*ow]` back to image `[c,h,w]`.
#[allow(clippy::too_many_arguments)]
pub fn col2im(
    col: &[f32],
    img: &mut [f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, kh, stride, pad);
    let ow = conv_out(w, kw, stride, pad);
    img.fill(0.0);
    let mut row = 0usize;
    for ch in 0..c {
        for ky in 0..kh {
            for kx in 0..kw {
                let src = &col[row * oh * ow..(row + 1) * oh * ow];
                let mut idx = 0usize;
                for oy in 0..oh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        idx += ow;
                        continue;
                    }
                    for ox in 0..ow {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix >= 0 && ix < w as isize {
                            img[ch * h * w + iy as usize * w + ix as usize] += src[idx];
                        }
                        idx += 1;
                    }
                }
                row += 1;
            }
        }
    }
}

/// Pooling selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    /// max pooling
    Max,
    /// average pooling
    Avg,
}

/// Pooling forward for one NCHW batch. `argmax` (same size as output)
/// records winning input indices for max-pool backward; ignored for avg.
#[allow(clippy::too_many_arguments)]
pub fn pool_forward(
    kind: PoolKind,
    x: &[f32],
    y: &mut [f32],
    argmax: &mut [f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, k, stride, pad);
    let ow = conv_out(w, k, stride, pad);
    debug_assert_eq!(y.len(), n * c * oh * ow);
    for img in 0..n {
        for ch in 0..c {
            let plane = &x[(img * c + ch) * h * w..(img * c + ch + 1) * h * w];
            let out_base = (img * c + ch) * oh * ow;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0usize;
                    let mut sum = 0.0f32;
                    let mut count = 0usize;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let idx = iy as usize * w + ix as usize;
                            let v = plane[idx];
                            if v > best {
                                best = v;
                                best_idx = idx;
                            }
                            sum += v;
                            count += 1;
                        }
                    }
                    let o = out_base + oy * ow + ox;
                    match kind {
                        PoolKind::Max => {
                            y[o] = best;
                            argmax[o] = best_idx as f32;
                        }
                        PoolKind::Avg => {
                            y[o] = if count > 0 { sum / count as f32 } else { 0.0 };
                        }
                    }
                }
            }
        }
    }
}

/// Pooling backward.
#[allow(clippy::too_many_arguments)]
pub fn pool_backward(
    kind: PoolKind,
    dy: &[f32],
    argmax: &[f32],
    dx: &mut [f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, k, stride, pad);
    let ow = conv_out(w, k, stride, pad);
    dx.fill(0.0);
    for img in 0..n {
        for ch in 0..c {
            let in_base = (img * c + ch) * h * w;
            let out_base = (img * c + ch) * oh * ow;
            for oy in 0..oh {
                for ox in 0..ow {
                    let o = out_base + oy * ow + ox;
                    match kind {
                        PoolKind::Max => {
                            dx[in_base + argmax[o] as usize] += dy[o];
                        }
                        PoolKind::Avg => {
                            // distribute evenly over the valid window
                            let mut cells = Vec::with_capacity(k * k);
                            for ky in 0..k {
                                let iy = (oy * stride + ky) as isize - pad as isize;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                for kx in 0..k {
                                    let ix = (ox * stride + kx) as isize - pad as isize;
                                    if ix >= 0 && ix < w as isize {
                                        cells.push(iy as usize * w + ix as usize);
                                    }
                                }
                            }
                            if !cells.is_empty() {
                                let g = dy[o] / cells.len() as f32;
                                for idx in cells {
                                    dx[in_base + idx] += g;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// BatchNorm forward (training mode) over NCHW, per-channel statistics.
/// Writes normalized output plus per-channel `save_mean` / `save_invstd`
/// needed by backward.
#[allow(clippy::too_many_arguments)]
pub fn batchnorm_forward(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    y: &mut [f32],
    save_mean: &mut [f32],
    save_invstd: &mut [f32],
    n: usize,
    c: usize,
    spatial: usize,
    eps: f32,
) {
    let count = (n * spatial) as f32;
    for ch in 0..c {
        let mut mean = 0.0f32;
        for img in 0..n {
            let base = (img * c + ch) * spatial;
            for s in 0..spatial {
                mean += x[base + s];
            }
        }
        mean /= count;
        let mut var = 0.0f32;
        for img in 0..n {
            let base = (img * c + ch) * spatial;
            for s in 0..spatial {
                let d = x[base + s] - mean;
                var += d * d;
            }
        }
        var /= count;
        let invstd = 1.0 / (var + eps).sqrt();
        save_mean[ch] = mean;
        save_invstd[ch] = invstd;
        let (g, b) = (gamma[ch], beta[ch]);
        for img in 0..n {
            let base = (img * c + ch) * spatial;
            for s in 0..spatial {
                y[base + s] = (x[base + s] - mean) * invstd * g + b;
            }
        }
    }
}

/// BatchNorm backward. Returns gradients for x, gamma, beta.
#[allow(clippy::too_many_arguments)]
pub fn batchnorm_backward(
    x: &[f32],
    dy: &[f32],
    gamma: &[f32],
    save_mean: &[f32],
    save_invstd: &[f32],
    dx: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    n: usize,
    c: usize,
    spatial: usize,
) {
    let count = (n * spatial) as f32;
    for ch in 0..c {
        let mean = save_mean[ch];
        let invstd = save_invstd[ch];
        let mut sum_dy = 0.0f32;
        let mut sum_dy_xhat = 0.0f32;
        for img in 0..n {
            let base = (img * c + ch) * spatial;
            for s in 0..spatial {
                let xhat = (x[base + s] - mean) * invstd;
                sum_dy += dy[base + s];
                sum_dy_xhat += dy[base + s] * xhat;
            }
        }
        dgamma[ch] = sum_dy_xhat;
        dbeta[ch] = sum_dy;
        let g = gamma[ch];
        for img in 0..n {
            let base = (img * c + ch) * spatial;
            for s in 0..spatial {
                let xhat = (x[base + s] - mean) * invstd;
                dx[base + s] =
                    g * invstd * (dy[base + s] - sum_dy / count - xhat * sum_dy_xhat / count);
            }
        }
    }
}

/// Row-wise argmax of `[m,n]` into `out[m]`.
pub fn argmax_rows(x: &[f32], out: &mut [f32], m: usize, n: usize) {
    for i in 0..m {
        let row = &x[i * n..(i + 1) * n];
        let mut best = 0usize;
        for j in 1..n {
            if row[j] > row[best] {
                best = j;
            }
        }
        out[i] = best as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = crate::util::Rng::seed_from_u64(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (8, 8, 8), (13, 7, 17)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let mut c = vec![0.0; m * n];
            gemm(&a, &b, &mut c, m, k, n, 0.0);
            let want = naive_gemm(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_nt_tn_match_transposed_naive() {
        let mut rng = crate::util::Rng::seed_from_u64(2);
        let (m, k, n) = (5, 7, 4);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        // b_t is [n,k]
        let mut b_t = vec![0.0; n * k];
        for p in 0..k {
            for j in 0..n {
                b_t[j * k + p] = b[p * n + j];
            }
        }
        let mut c1 = vec![0.0; m * n];
        gemm_nt(&a, &b_t, &mut c1, m, k, n, 0.0);
        let want = naive_gemm(&a, &b, m, k, n);
        for (x, y) in c1.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
        // a_t is [k,m]
        let mut a_t = vec![0.0; k * m];
        for i in 0..m {
            for p in 0..k {
                a_t[p * m + i] = a[i * k + p];
            }
        }
        let mut c2 = vec![0.0; m * n];
        gemm_tn(&a_t, &b, &mut c2, m, k, n, 0.0);
        for (x, y) in c2.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_beta_accumulates() {
        let a = [1.0, 0.0, 0.0, 1.0]; // identity 2x2
        let b = [1.0, 2.0, 3.0, 4.0];
        let mut c = [10.0, 10.0, 10.0, 10.0];
        gemm(&a, &b, &mut c, 2, 2, 2, 1.0);
        assert_eq!(c, [11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = [1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let mut y = [0.0; 6];
        softmax_rows(&x, &mut y, 2, 3);
        for i in 0..2 {
            let s: f32 = y[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        // invariant to shift: rows with equal relative offsets equal probs
        assert!((y[0] - y[3]).abs() < 1e-6);
    }

    #[test]
    fn softmax_xent_gradient_check() {
        // numeric gradient of mean CE wrt logits
        let m = 2;
        let n = 4;
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let logits: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let labels = [1.0, 3.0];
        let loss_of = |lg: &[f32]| {
            let mut p = vec![0.0; m * n];
            softmax_rows(lg, &mut p, m, n);
            xent_loss(&p, &labels, m, n)
        };
        let mut probs = vec![0.0; m * n];
        softmax_rows(&logits, &mut probs, m, n);
        let mut grad = vec![0.0; m * n];
        softmax_xent_backward(&probs, &labels, &mut grad, m, n);
        let eps = 1e-3;
        for i in 0..m * n {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let num = (loss_of(&lp) - loss_of(&lm)) / (2.0 * eps);
            assert!((num - grad[i]).abs() < 1e-3, "i={i}: {num} vs {}", grad[i]);
        }
    }

    #[test]
    fn im2col_col2im_roundtrip_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> (adjoint property)
        let (c, h, w, kh, kw, s, p) = (2, 5, 5, 3, 3, 1, 1);
        let oh = conv_out(h, kh, s, p);
        let ow = conv_out(w, kw, s, p);
        let mut rng = crate::util::Rng::seed_from_u64(6);
        let x: Vec<f32> = (0..c * h * w).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..c * kh * kw * oh * ow).map(|_| rng.normal()).collect();
        let mut col = vec![0.0; c * kh * kw * oh * ow];
        im2col(&x, &mut col, c, h, w, kh, kw, s, p);
        let mut img = vec![0.0; c * h * w];
        col2im(&y, &mut img, c, h, w, kh, kw, s, p);
        let lhs: f32 = col.iter().zip(&y).map(|(a, b)| a * b).sum();
        let rhs: f32 = x.iter().zip(&img).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() / lhs.abs().max(1.0) < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn maxpool_forward_simple() {
        // 1x1x4x4, k=2, s=2
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut y = vec![0.0; 4];
        let mut am = vec![0.0; 4];
        pool_forward(PoolKind::Max, &x, &mut y, &mut am, 1, 1, 4, 4, 2, 2, 0);
        assert_eq!(y, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn avgpool_forward_simple() {
        let x = vec![1.0, 3.0, 5.0, 7.0]; // 1x1x2x2, k=2 s=2
        let mut y = vec![0.0; 1];
        let mut am = vec![0.0; 1];
        pool_forward(PoolKind::Avg, &x, &mut y, &mut am, 1, 1, 2, 2, 2, 2, 0);
        assert_eq!(y, vec![4.0]);
    }

    #[test]
    fn maxpool_backward_routes_gradient() {
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut y = vec![0.0; 4];
        let mut am = vec![0.0; 4];
        pool_forward(PoolKind::Max, &x, &mut y, &mut am, 1, 1, 4, 4, 2, 2, 0);
        let dy = vec![1.0, 2.0, 3.0, 4.0];
        let mut dx = vec![0.0; 16];
        pool_backward(PoolKind::Max, &dy, &am, &mut dx, 1, 1, 4, 4, 2, 2, 0);
        assert_eq!(dx[5], 1.0);
        assert_eq!(dx[7], 2.0);
        assert_eq!(dx[13], 3.0);
        assert_eq!(dx[15], 4.0);
        assert_eq!(dx.iter().sum::<f32>(), 10.0);
    }

    #[test]
    fn batchnorm_normalizes() {
        let (n, c, sp) = (4, 2, 8);
        let mut rng = crate::util::Rng::seed_from_u64(7);
        let x: Vec<f32> = (0..n * c * sp).map(|_| rng.normal_with(3.0, 2.0)).collect();
        let gamma = vec![1.0; c];
        let beta = vec![0.0; c];
        let mut y = vec![0.0; n * c * sp];
        let mut sm = vec![0.0; c];
        let mut si = vec![0.0; c];
        batchnorm_forward(&x, &gamma, &beta, &mut y, &mut sm, &mut si, n, c, sp, 1e-5);
        // per-channel mean ~0, var ~1
        for ch in 0..c {
            let mut mean = 0.0;
            let mut var = 0.0;
            let cnt = (n * sp) as f32;
            for img in 0..n {
                for s in 0..sp {
                    mean += y[(img * c + ch) * sp + s];
                }
            }
            mean /= cnt;
            for img in 0..n {
                for s in 0..sp {
                    let d = y[(img * c + ch) * sp + s] - mean;
                    var += d * d;
                }
            }
            var /= cnt;
            assert!(mean.abs() < 1e-4, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn batchnorm_gradient_check() {
        let (n, c, sp) = (2, 1, 3);
        let mut rng = crate::util::Rng::seed_from_u64(8);
        let x: Vec<f32> = (0..n * c * sp).map(|_| rng.normal()).collect();
        let gamma = vec![1.3; c];
        let beta = vec![0.2; c];
        let dy: Vec<f32> = (0..n * c * sp).map(|_| rng.normal()).collect();
        let fwd = |xx: &[f32]| {
            let mut y = vec![0.0; n * c * sp];
            let mut sm = vec![0.0; c];
            let mut si = vec![0.0; c];
            batchnorm_forward(xx, &gamma, &beta, &mut y, &mut sm, &mut si, n, c, sp, 1e-5);
            y
        };
        let y0 = fwd(&x);
        let _ = y0;
        let mut sm = vec![0.0; c];
        let mut si = vec![0.0; c];
        let mut y = vec![0.0; n * c * sp];
        batchnorm_forward(&x, &gamma, &beta, &mut y, &mut sm, &mut si, n, c, sp, 1e-5);
        let mut dx = vec![0.0; n * c * sp];
        let mut dg = vec![0.0; c];
        let mut db = vec![0.0; c];
        batchnorm_backward(&x, &dy, &gamma, &sm, &si, &mut dx, &mut dg, &mut db, n, c, sp);
        let eps = 1e-2;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let f = |yy: Vec<f32>| -> f32 { yy.iter().zip(&dy).map(|(a, b)| a * b).sum() };
            let num = (f(fwd(&xp)) - f(fwd(&xm))) / (2.0 * eps);
            assert!((num - dx[i]).abs() < 2e-2, "i={i}: {num} vs {}", dx[i]);
        }
    }

    #[test]
    fn bias_add_and_grad() {
        let mut x = vec![0.0; 6];
        bias_add(&mut x, &[1.0, 2.0, 3.0], 2, 3);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
        let mut db = vec![0.0; 3];
        bias_grad(&x, &mut db, 2, 3, 0.0);
        assert_eq!(db, vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn argmax_rows_works() {
        let x = [0.1, 0.9, 0.0, 5.0, -1.0, 2.0];
        let mut out = [0.0; 2];
        argmax_rows(&x, &mut out, 2, 3);
        assert_eq!(out, [1.0, 0.0]);
    }
}

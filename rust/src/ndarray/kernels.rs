//! Raw f32 math kernels on slices.
//!
//! These are the CPU "big operations" (paper §3.1: *"we manually
//! implemented well-optimized big operations, such as a layer in neural
//! network"*).  Both the imperative [`NDArray`](super::NDArray) methods and
//! the graph executor's native operator backend dispatch here, so the two
//! programming paradigms share one set of kernels — exactly the unified-
//! backend story of the paper.
//!
//! Layout conventions: matrices are row-major `[rows, cols]`; images are
//! NCHW.
//!
//! # Performance architecture
//!
//! The GEMM family is a cache-blocked, packed design (BLIS-style): the
//! operand matrices are cut into `MC x KC` / `KC x NC` blocks, packed into
//! thread-local contiguous panels sized for L1/L2 residency, and consumed
//! by an `MR x NR` register-tile micro-kernel whose inner loop is 8-lane
//! vectorizable.  Big kernels additionally parallelize *within* one
//! operation via [`crate::util::parallel_for_cost`]: GEMM over row
//! panels, conv over images, pooling/batchnorm over planes/channels,
//! softmax over row chunks.
//!
//! Two invariants every parallel kernel here maintains:
//!
//! 1. **Chunk partitions are pure functions of the problem shape** —
//!    never of the thread count — and each output element is produced by
//!    exactly one chunk with a fixed serial instruction order.  Results
//!    are therefore *bitwise identical* for every intra-op thread count,
//!    including serial execution.
//! 2. **Cost gating**: kernels estimate their FLOPs and stay serial below
//!    [`crate::util::INTRA_MIN_COST`], so small ops never pay fan-out
//!    latency and the engine's inter-op parallelism remains the primary
//!    source of concurrency for graphs of small operations.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::profile::{Category, SpanTimer};
use crate::util::parallel_for_cost;

/// When set, the GEMM family runs a deliberately *unoptimized* inner loop
/// (j-i-p order, strided, not vectorizable) — the stand-in for a
/// previous-generation kernel library (the paper's Figure 6 attributes
/// TensorFlow's 2x gap to CUDNN v2 vs v3).  See `cargo bench --bench
/// fig6_convnet`, mode `tf-old`.
static REFERENCE_MODE: AtomicBool = AtomicBool::new(false);

/// Switch the GEMM family between the optimized and the reference (slow)
/// implementations.  Affects the whole process; benches only.
pub fn set_reference_kernels(on: bool) {
    REFERENCE_MODE.store(on, Ordering::SeqCst);
}

/// Whether reference (slow) kernels are active.
pub fn reference_kernels() -> bool {
    REFERENCE_MODE.load(Ordering::SeqCst)
}

/// Naive j-i-p GEMM used in reference mode: column-at-a-time with strided
/// b access — roughly the memory-access pattern cost of an old kernel
/// generation.  `ta`/`tb` transpose a/b.  Also the correctness oracle for
/// the blocked implementation's property tests.
#[inline(never)]
pub fn gemm_reference(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    beta: f32,
    ta: bool,
    tb: bool,
) {
    let ai = |i: usize, p: usize| if ta { a[p * m + i] } else { a[i * k + p] };
    let bi = |p: usize, j: usize| if tb { b[j * k + p] } else { b[p * n + j] };
    for j in 0..n {
        for i in 0..m {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += ai(i, p) * bi(p, j);
            }
            let dst = &mut c[i * n + j];
            *dst = if beta == 0.0 { acc } else { *dst * beta + acc };
        }
    }
}

/// The seed generation's single-threaded i-k-j GEMM (saxpy over contiguous
/// rows of b and c).  Kept as the before/after baseline for `cargo bench
/// --bench kernels`; the branchy `a[i,p] == 0.0` skip the seed carried has
/// been removed — it defeated vectorization on dense inputs and mispriced
/// the baseline.
pub fn gemm_ikj(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, beta: f32) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    scale_inplace(c, beta);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let aip = a[i * k + p];
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
}

// ---------------------------------------------------------------------
// Blocked, packed, intra-op-parallel GEMM
// ---------------------------------------------------------------------

/// Row-panel height of one cache block of A (fits L2 next to a B panel).
const MC: usize = 64;
/// Depth of one cache block (packed A panel: MC*KC*4 = 64 KiB).
const KC: usize = 256;
/// Column width of one packed B panel (KC*NC*4 = 256 KiB, L2-resident).
const NC: usize = 256;
/// Micro-tile rows: 8x8 f32 accumulators live in registers.
const MR: usize = 8;
/// Micro-tile columns (one 8-lane vector).
const NR: usize = 8;

/// Below this *per-row* FLOP count (`2*k*n`) the packing machinery costs
/// more than it saves; use the plain loop-nest fast paths.
///
/// The gate is deliberately a function of `(k, n)` only — never of the
/// row count `m` — so any single output row takes the same code path (and
/// therefore the same f32 summation order) for **every** batch size.
/// Combined with the row-pure blocked path this makes each GEMM output
/// row bitwise identical whether it is computed in a batch of 1 or 64 —
/// the losslessness invariant the serving layer (`serve/`) relies on when
/// it coalesces single-sample requests into batches.
const SMALL_GEMM_ROW_FLOPS: f64 = 4096.0;

thread_local! {
    /// Per-thread packing buffers (A block, B panel) reused across calls.
    static PACK_BUFS: RefCell<(Vec<f32>, Vec<f32>)> = const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Mutable-slice smuggler for disjoint-chunk parallel writes.
///
/// Every parallel kernel in this module partitions its output into
/// disjoint index ranges, one per chunk; this wrapper lets the `Fn`
/// closure reconstruct its chunk's exclusive sub-slice.
#[derive(Clone, Copy)]
struct SendMut(*mut f32);
unsafe impl Send for SendMut {}
unsafe impl Sync for SendMut {}

impl SendMut {
    fn new(s: &mut [f32]) -> Self {
        SendMut(s.as_mut_ptr())
    }

    /// Reborrow `[off, off + len)` of the wrapped buffer.
    ///
    /// # Safety
    /// Caller must guarantee the range is in bounds and that no two
    /// concurrent chunks overlap their ranges.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice(&self, off: usize, len: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.0.add(off), len)
    }
}

/// `c = beta * c` with the conventional special cases.
#[inline]
fn scale_inplace(c: &mut [f32], beta: f32) {
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for x in c.iter_mut() {
            *x *= beta;
        }
    }
}

/// Pack the `mc x kc` block of A starting at `(i0, p0)` into micro-panels
/// of MR rows: panel-major, then p-major, then MR consecutive row entries
/// (zero-padded past `mc`).  `a(i, p) = a[i*ras + p*cas]` absorbs the
/// transpose variants.
fn pack_a(
    buf: &mut Vec<f32>,
    a: &[f32],
    ras: usize,
    cas: usize,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
) {
    buf.clear();
    buf.reserve(mc.div_ceil(MR) * MR * kc);
    for ir in (0..mc).step_by(MR) {
        let rows = MR.min(mc - ir);
        for p in 0..kc {
            for r in 0..MR {
                buf.push(if r < rows {
                    a[(i0 + ir + r) * ras + (p0 + p) * cas]
                } else {
                    0.0
                });
            }
        }
    }
}

/// Pack the `kc x nc` panel of B starting at `(p0, j0)` into micro-panels
/// of NR columns: panel-major, then p-major, then NR consecutive column
/// entries (zero-padded past `nc`).  `b(p, j) = b[p*rbs + j*cbs]`.
fn pack_b(
    buf: &mut Vec<f32>,
    b: &[f32],
    rbs: usize,
    cbs: usize,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
) {
    buf.clear();
    buf.reserve(nc.div_ceil(NR) * NR * kc);
    for jc in (0..nc).step_by(NR) {
        let cols = NR.min(nc - jc);
        for p in 0..kc {
            for j in 0..NR {
                buf.push(if j < cols {
                    b[(p0 + p) * rbs + (j0 + jc + j) * cbs]
                } else {
                    0.0
                });
            }
        }
    }
}

/// The register-tile micro-kernel: `C[rows x cols] += Apanel @ Bpanel`
/// where the panels are the packed MR/NR layouts above.  The accumulator
/// block is a fixed `[MR][NR]` array so LLVM keeps it in vector registers
/// and turns the inner loop into broadcast-FMA over 8 lanes.
#[inline]
fn microkernel(
    apanel: &[f32],
    bpanel: &[f32],
    kc: usize,
    c: &mut [f32],
    coff: usize,
    ldc: usize,
    rows: usize,
    cols: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let ar: &[f32; MR] = apanel[p * MR..p * MR + MR].try_into().unwrap();
        let br: &[f32; NR] = bpanel[p * NR..p * NR + NR].try_into().unwrap();
        for r in 0..MR {
            let av = ar[r];
            for j in 0..NR {
                acc[r][j] += av * br[j];
            }
        }
    }
    if rows == MR && cols == NR {
        for r in 0..MR {
            let crow = &mut c[coff + r * ldc..coff + r * ldc + NR];
            for (j, dst) in crow.iter_mut().enumerate() {
                *dst += acc[r][j];
            }
        }
    } else {
        for r in 0..rows {
            let crow = &mut c[coff + r * ldc..coff + r * ldc + cols];
            for (j, dst) in crow.iter_mut().enumerate() {
                *dst += acc[r][j];
            }
        }
    }
}

/// One step of a kernel-level epilogue chain: the executable mirror of
/// `graph::FusedStep`, with `Binary` operand slices bound.
#[derive(Clone, Copy)]
pub enum EpStep<'a> {
    /// Apply an activation.
    Act(ActKind),
    /// Add a constant.
    AddScalar(f32),
    /// Multiply by a constant.
    MulScalar(f32),
    /// Combine elementwise with the same-index element of the operand.
    Binary(EwBinary, &'a [f32]),
}

/// A fused post-GEMM/conv elementwise chain (graph-compiler epilogue
/// fusion): an optional broadcast bias followed by [`EpStep`]s, applied
/// to each output tile right after its accumulation completes — while
/// the tile is still cache-hot.
///
/// **Bitwise contract.**  Per element, the scalar instruction sequence
/// (bias add, then each step in order) is exactly the one the unfused
/// kernel pipeline (`bias_add`, `act_forward`, scalar/binary sweeps)
/// executes, and every step is per-element independent.  An element's
/// final value therefore never depends on *when* or on *which thread*
/// the epilogue ran, so fused output is bitwise identical to the
/// unfused composition for any thread count and any tile schedule —
/// the same shape-purity argument as the GEMM row dispatch.
#[derive(Clone, Copy)]
pub struct Epilogue<'a> {
    /// Broadcast bias (`None` = no bias).
    pub bias: Option<&'a [f32]>,
    /// Bias axis: per output row (`bias[i]`, conv filters) when true,
    /// per output column (`bias[j]`, FC hidden units) when false.
    pub bias_per_row: bool,
    /// Steps applied in order after the bias.
    pub steps: &'a [EpStep<'a>],
}

impl Epilogue<'_> {
    /// Apply the chain to the sub-block `[row0, row0+nrows) x
    /// [col0, col0+ncols)` of a row-major `[.., n]` output, where
    /// `crows` holds the rows starting at global row `row0` (row `i`
    /// lives at `(i - row0) * n`).  `Binary` operands are indexed at
    /// `operand_base + i * n + j`.
    pub fn apply_block(
        &self,
        crows: &mut [f32],
        row0: usize,
        nrows: usize,
        col0: usize,
        ncols: usize,
        n: usize,
        operand_base: usize,
    ) {
        for r in 0..nrows {
            let gi = row0 + r;
            let row = &mut crows[r * n + col0..r * n + col0 + ncols];
            if let Some(bias) = self.bias {
                if self.bias_per_row {
                    let bf = bias[gi];
                    for v in row.iter_mut() {
                        *v += bf;
                    }
                } else {
                    for (j, v) in row.iter_mut().enumerate() {
                        *v += bias[col0 + j];
                    }
                }
            }
            for step in self.steps {
                match step {
                    EpStep::Act(ActKind::Relu) => {
                        for v in row.iter_mut() {
                            *v = v.max(0.0);
                        }
                    }
                    EpStep::Act(ActKind::Tanh) => {
                        for v in row.iter_mut() {
                            *v = v.tanh();
                        }
                    }
                    EpStep::Act(ActKind::Sigmoid) => {
                        for v in row.iter_mut() {
                            *v = 1.0 / (1.0 + (-*v).exp());
                        }
                    }
                    EpStep::AddScalar(s) => {
                        for v in row.iter_mut() {
                            *v += s;
                        }
                    }
                    EpStep::MulScalar(s) => {
                        for v in row.iter_mut() {
                            *v *= s;
                        }
                    }
                    EpStep::Binary(op, operand) => {
                        let base = operand_base + gi * n + col0;
                        let o = &operand[base..base + ncols];
                        match op {
                            EwBinary::Add => {
                                for (v, b) in row.iter_mut().zip(o) {
                                    *v += b;
                                }
                            }
                            EwBinary::Sub => {
                                for (v, b) in row.iter_mut().zip(o) {
                                    *v -= b;
                                }
                            }
                            EwBinary::Mul => {
                                for (v, b) in row.iter_mut().zip(o) {
                                    *v *= b;
                                }
                            }
                            EwBinary::Div => {
                                for (v, b) in row.iter_mut().zip(o) {
                                    *v /= b;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Serial blocked GEMM over the row range `[m0, m1)` of the output:
/// `crows` holds exactly those rows (row `i` of C lives at
/// `(i - m0) * n`).  Loop order is jc -> pc -> ic so every output element
/// accumulates its KC-block contributions in the same order regardless of
/// how `[0, m)` is split across threads — the bitwise-determinism
/// invariant.
///
/// When `ep` is set, the epilogue runs on each `[m0, m1) x jc-panel`
/// region right after its last KC block lands, i.e. while the panel is
/// still L2-resident (per-element order-independent, so bits don't
/// change — see [`Epilogue`]).
#[allow(clippy::too_many_arguments)]
fn gemm_block_rows(
    a: &[f32],
    ras: usize,
    cas: usize,
    b: &[f32],
    rbs: usize,
    cbs: usize,
    crows: &mut [f32],
    m0: usize,
    m1: usize,
    k: usize,
    n: usize,
    ep: Option<&Epilogue>,
) {
    PACK_BUFS.with(|bufs| {
        let (abuf, bbuf) = &mut *bufs.borrow_mut();
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            for pc in (0..k).step_by(KC) {
                let kc = KC.min(k - pc);
                pack_b(bbuf, b, rbs, cbs, pc, kc, jc, nc);
                for ic in (m0..m1).step_by(MC) {
                    let mc = MC.min(m1 - ic);
                    pack_a(abuf, a, ras, cas, ic, mc, pc, kc);
                    let n_apanels = mc.div_ceil(MR);
                    let n_bpanels = nc.div_ceil(NR);
                    for ap in 0..n_apanels {
                        let rows = MR.min(mc - ap * MR);
                        let apanel = &abuf[ap * MR * kc..(ap + 1) * MR * kc];
                        for bp in 0..n_bpanels {
                            let cols = NR.min(nc - bp * NR);
                            let bpanel = &bbuf[bp * NR * kc..(bp + 1) * NR * kc];
                            let coff = (ic - m0 + ap * MR) * n + jc + bp * NR;
                            microkernel(apanel, bpanel, kc, crows, coff, n, rows, cols);
                        }
                    }
                }
            }
            if let Some(ep) = ep {
                ep.apply_block(crows, m0, m1 - m0, jc, nc, n, 0);
            }
        }
    });
}

/// Small-shape fast paths: below [`SMALL_GEMM_ROW_FLOPS`] per row the
/// simple loop nests beat the packing machinery.  Every path computes
/// row `i` of C as a pure function of row `i` of A (and all of B) with a
/// fixed per-row summation order, so results are independent of `m`.
#[allow(clippy::too_many_arguments)]
fn gemm_small(
    a: &[f32],
    ta: bool,
    b: &[f32],
    tb: bool,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    beta: f32,
) {
    match (ta, tb) {
        // i-k-j: inner saxpy over contiguous rows of b and c.
        (false, false) => gemm_ikj(a, b, c, m, k, n, beta),
        (false, true) => {
            // both operands row-contiguous: lane-parallel dot per output.
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                for j in 0..n {
                    let acc = vdot(arow, &b[j * k..(j + 1) * k]);
                    let dst = &mut c[i * n + j];
                    *dst = if beta == 0.0 { acc } else { *dst * beta + acc };
                }
            }
        }
        (true, false) => {
            // p-i-j: rank-1 updates from rows of a^T and b.
            scale_inplace(c, beta);
            for p in 0..k {
                let arow = &a[p * m..(p + 1) * m];
                let brow = &b[p * n..(p + 1) * n];
                for i in 0..m {
                    let aip = arow[i];
                    let crow = &mut c[i * n..(i + 1) * n];
                    for j in 0..n {
                        crow[j] += aip * brow[j];
                    }
                }
            }
        }
        (true, true) => gemm_reference(a, b, c, m, k, n, beta, true, true),
    }
}

/// Shared GEMM driver: `C = A' @ B' + beta * C` where the primes denote
/// the optional transposes.  Dispatches small shapes to plain loop nests
/// and everything else to the blocked path, parallelized over MC-row
/// panels of C (each chunk owns a disjoint, contiguous slice of C).
#[allow(clippy::too_many_arguments)]
fn gemm_driver(
    a: &[f32],
    ta: bool,
    b: &[f32],
    tb: bool,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    beta: f32,
    ep: Option<&Epilogue>,
) {
    let row_flops = 2.0 * k as f64 * n as f64;
    let flops = row_flops * m as f64;
    if row_flops < SMALL_GEMM_ROW_FLOPS {
        // Cheap rows, but possibly many of them: keep the loop-nest
        // paths yet recover intra-op parallelism for tall-skinny shapes
        // by row-chunking.  The chunk partition is a pure function of
        // shape and each row's summation order is untouched, so
        // row-purity (and thread-count determinism) still holds.  The
        // transposed-A variants index A column-wise and cannot slice by
        // rows; they stay serial (their callers' shapes put k*n above
        // the gate in practice).
        if !ta {
            let cp = SendMut::new(c);
            parallel_for_cost(m, MC, flops, |rows| {
                let mr = rows.end - rows.start;
                let crows = unsafe { cp.slice(rows.start * n, mr * n) };
                let arows = &a[rows.start * k..rows.end * k];
                gemm_small(arows, false, b, tb, crows, mr, k, n, beta);
                if let Some(ep) = ep {
                    ep.apply_block(crows, rows.start, mr, 0, n, n, 0);
                }
            });
            return;
        }
        gemm_small(a, ta, b, tb, c, m, k, n, beta);
        if let Some(ep) = ep {
            ep.apply_block(c, 0, m, 0, n, n, 0);
        }
        return;
    }
    let (ras, cas) = if ta { (1, m) } else { (k, 1) };
    let (rbs, cbs) = if tb { (1, k) } else { (n, 1) };
    let cp = SendMut::new(c);
    parallel_for_cost(m, MC, flops, |rows| {
        // SAFETY: row ranges from parallel_for are disjoint, and rows
        // [lo, hi) of row-major C occupy the disjoint slice
        // [lo*n, hi*n).
        let crows = unsafe { cp.slice(rows.start * n, (rows.end - rows.start) * n) };
        scale_inplace(crows, beta);
        gemm_block_rows(a, ras, cas, b, rbs, cbs, crows, rows.start, rows.end, k, n, ep);
    });
}

/// `c = a @ b` where a is `[m,k]`, b is `[k,n]`, c is `[m,n]`.
/// `beta == 0.0` overwrites c, `beta == 1.0` accumulates.
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, beta: f32) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let prof = SpanTimer::start();
    if reference_kernels() {
        gemm_reference(a, b, c, m, k, n, beta, false, false);
    } else {
        gemm_driver(a, false, b, false, c, m, k, n, beta, None);
    }
    prof.finish(Category::Kernel, "kernel.gemm", 0, (2 * m * k * n) as u64, 0);
}

/// Vectorizable dot product: 8 independent accumulator lanes so LLVM can
/// keep SIMD FMAs in flight without a loop-carried dependence.
#[inline]
fn vdot(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let x = &a[c * 8..c * 8 + 8];
        let y = &b[c * 8..c * 8 + 8];
        for l in 0..8 {
            lanes[l] += x[l] * y[l];
        }
    }
    let mut acc = lanes.iter().sum::<f32>();
    for p in chunks * 8..a.len() {
        acc += a[p] * b[p];
    }
    acc
}

/// `c = a @ b^T` where a is `[m,k]`, b is `[n,k]`, c is `[m,n]`.
///
/// This is the FullyConnected-forward shape (weights stored `[out, in]`),
/// i.e. the hottest kernel in training.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, beta: f32) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let prof = SpanTimer::start();
    if reference_kernels() {
        gemm_reference(a, b, c, m, k, n, beta, false, true);
    } else {
        gemm_driver(a, false, b, true, c, m, k, n, beta, None);
    }
    prof.finish(Category::Kernel, "kernel.gemm_nt", 0, (2 * m * k * n) as u64, 0);
}

/// `c = epilogue(a @ b^T)`: FullyConnected forward with the fused
/// epilogue (bias/activation/elementwise chain) applied to each output
/// tile while it is cache-hot instead of in separate full-tensor
/// sweeps.  Bitwise identical to `gemm_nt` followed by the unfused
/// elementwise kernels for any thread count (see [`Epilogue`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_ep(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    beta: f32,
    ep: &Epilogue,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let prof = SpanTimer::start();
    if reference_kernels() {
        gemm_reference(a, b, c, m, k, n, beta, false, true);
        ep.apply_block(c, 0, m, 0, n, n, 0);
    } else {
        gemm_driver(a, false, b, true, c, m, k, n, beta, Some(ep));
    }
    prof.finish(Category::Kernel, "kernel.gemm_nt_ep", 0, (2 * m * k * n) as u64, 0);
}

/// `c = a^T @ b` where a is `[k,m]`, b is `[k,n]`, c is `[m,n]`.
pub fn gemm_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, beta: f32) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let prof = SpanTimer::start();
    if reference_kernels() {
        gemm_reference(a, b, c, m, k, n, beta, true, false);
    } else {
        gemm_driver(a, true, b, false, c, m, k, n, beta, None);
    }
    prof.finish(Category::Kernel, "kernel.gemm_tn", 0, (2 * m * k * n) as u64, 0);
}

// ---------------------------------------------------------------------
// Vector / elementwise kernels
// ---------------------------------------------------------------------

/// Element chunk size for parallel elementwise sweeps (128 KiB of f32).
const EW_GRAIN: usize = 32 * 1024;

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let yp = SendMut::new(y);
    let len = x.len();
    parallel_for_cost(len, EW_GRAIN, len as f64, |r| {
        let yr = unsafe { yp.slice(r.start, r.end - r.start) };
        for (yi, xi) in yr.iter_mut().zip(&x[r]) {
            *yi += alpha * xi;
        }
    });
}

/// `y = alpha * x + beta * y` (general scaled update).
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let yp = SendMut::new(y);
    let len = x.len();
    parallel_for_cost(len, EW_GRAIN, 2.0 * len as f64, |r| {
        let yr = unsafe { yp.slice(r.start, r.end - r.start) };
        for (yi, xi) in yr.iter_mut().zip(&x[r]) {
            *yi = alpha * xi + beta * *yi;
        }
    });
}

/// Elementwise binary op.
pub fn ew_binary(op: EwBinary, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    let op_fn = |r: std::ops::Range<usize>, out: &mut [f32]| {
        let (ar, br) = (&a[r.clone()], &b[r]);
        match op {
            EwBinary::Add => {
                for i in 0..ar.len() {
                    out[i] = ar[i] + br[i];
                }
            }
            EwBinary::Sub => {
                for i in 0..ar.len() {
                    out[i] = ar[i] - br[i];
                }
            }
            EwBinary::Mul => {
                for i in 0..ar.len() {
                    out[i] = ar[i] * br[i];
                }
            }
            EwBinary::Div => {
                for i in 0..ar.len() {
                    out[i] = ar[i] / br[i];
                }
            }
        }
    };
    let outp = SendMut::new(out);
    let len = a.len();
    parallel_for_cost(len, EW_GRAIN, len as f64, |r| {
        let o = unsafe { outp.slice(r.start, r.end - r.start) };
        op_fn(r, o);
    });
}

/// Elementwise binary operator selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwBinary {
    /// `a + b`
    Add,
    /// `a - b`
    Sub,
    /// `a * b`
    Mul,
    /// `a / b`
    Div,
}

/// Activation function selector (paper's `Activation(act_type=...)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    /// max(0, x)
    Relu,
    /// tanh(x)
    Tanh,
    /// 1/(1+exp(-x))
    Sigmoid,
}

/// Forward activation.
pub fn act_forward(kind: ActKind, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let yp = SendMut::new(y);
    let len = x.len();
    // tanh/sigmoid cost ~10 flops/element; relu is cheap but uniform
    // costing keeps the partition identical across kinds.
    parallel_for_cost(len, EW_GRAIN, 8.0 * len as f64, |r| {
        let yr = unsafe { yp.slice(r.start, r.end - r.start) };
        let xr = &x[r];
        match kind {
            ActKind::Relu => {
                for i in 0..xr.len() {
                    yr[i] = xr[i].max(0.0);
                }
            }
            ActKind::Tanh => {
                for i in 0..xr.len() {
                    yr[i] = xr[i].tanh();
                }
            }
            ActKind::Sigmoid => {
                for i in 0..xr.len() {
                    yr[i] = 1.0 / (1.0 + (-xr[i]).exp());
                }
            }
        }
    });
}

/// Backward activation: `dx = dy * f'(x)` computed from the *output* `y`
/// (all three supported activations allow this, which lets the forward
/// input be freed / reused inplace — important for the memory planner).
pub fn act_backward(kind: ActKind, dy: &[f32], y: &[f32], dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), y.len());
    debug_assert_eq!(dy.len(), dx.len());
    let dxp = SendMut::new(dx);
    let len = dy.len();
    parallel_for_cost(len, EW_GRAIN, 3.0 * len as f64, |r| {
        let dxr = unsafe { dxp.slice(r.start, r.end - r.start) };
        let (dyr, yr) = (&dy[r.clone()], &y[r]);
        match kind {
            ActKind::Relu => {
                for i in 0..dyr.len() {
                    dxr[i] = if yr[i] > 0.0 { dyr[i] } else { 0.0 };
                }
            }
            ActKind::Tanh => {
                for i in 0..dyr.len() {
                    dxr[i] = dyr[i] * (1.0 - yr[i] * yr[i]);
                }
            }
            ActKind::Sigmoid => {
                for i in 0..dyr.len() {
                    dxr[i] = dyr[i] * yr[i] * (1.0 - yr[i]);
                }
            }
        }
    });
}

/// Broadcast-add a bias vector of length `n` to each row of `[m,n]`.
pub fn bias_add(x: &mut [f32], bias: &[f32], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    let xp = SendMut::new(x);
    parallel_for_cost(m, row_grain(n), (m * n) as f64, |rows| {
        let xr = unsafe { xp.slice(rows.start * n, (rows.end - rows.start) * n) };
        for (ri, _) in rows.enumerate() {
            let row = &mut xr[ri * n..(ri + 1) * n];
            for j in 0..n {
                row[j] += bias[j];
            }
        }
    });
}

/// Gradient of bias: column sums of `[m,n]` into `dbias[n]`.
pub fn bias_grad(dy: &[f32], dbias: &mut [f32], m: usize, n: usize, beta: f32) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(dbias.len(), n);
    if beta == 0.0 {
        dbias.fill(0.0);
    }
    for i in 0..m {
        let row = &dy[i * n..(i + 1) * n];
        for j in 0..n {
            dbias[j] += row[j];
        }
    }
}

/// Rows per parallel chunk for row-wise kernels: ~8K elements per chunk,
/// a pure function of the row width (never of the thread count).
#[inline]
fn row_grain(n: usize) -> usize {
    (8192 / n.max(1)).max(1)
}

/// Row-wise softmax over `[m,n]`.
pub fn softmax_rows(x: &[f32], y: &mut [f32], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    debug_assert_eq!(y.len(), m * n);
    let yp = SendMut::new(y);
    parallel_for_cost(m, row_grain(n), 8.0 * (m * n) as f64, |rows| {
        for i in rows {
            let xr = &x[i * n..(i + 1) * n];
            let yr = unsafe { yp.slice(i * n, n) };
            let mx = xr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for j in 0..n {
                let e = (xr[j] - mx).exp();
                yr[j] = e;
                sum += e;
            }
            let inv = 1.0 / sum;
            for v in yr.iter_mut() {
                *v *= inv;
            }
        }
    });
}

/// Mean cross-entropy loss given row-softmax probabilities and integer
/// labels; returns the scalar loss.
pub fn xent_loss(probs: &[f32], labels: &[f32], m: usize, n: usize) -> f32 {
    let mut loss = 0.0;
    for i in 0..m {
        let t = labels[i] as usize;
        debug_assert!(t < n);
        loss -= probs[i * n + t].max(1e-12).ln();
    }
    loss / m as f32
}

/// Gradient of softmax + cross-entropy w.r.t. logits: `(p - onehot)/m`.
pub fn softmax_xent_backward(probs: &[f32], labels: &[f32], dx: &mut [f32], m: usize, n: usize) {
    debug_assert_eq!(probs.len(), m * n);
    debug_assert_eq!(dx.len(), m * n);
    let scale = 1.0 / m as f32;
    let dxp = SendMut::new(dx);
    parallel_for_cost(m, row_grain(n), 2.0 * (m * n) as f64, |rows| {
        for i in rows {
            let t = labels[i] as usize;
            let dxr = unsafe { dxp.slice(i * n, n) };
            let pr = &probs[i * n..(i + 1) * n];
            for j in 0..n {
                dxr[j] = scale * (pr[j] - if j == t { 1.0 } else { 0.0 });
            }
        }
    });
}

// ---------------------------------------------------------------------
// Convolution
// ---------------------------------------------------------------------

/// Convolution geometry helper: output spatial size.
#[inline]
pub fn conv_out(size: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    (size + 2 * pad - kernel) / stride + 1
}

/// im2col for NCHW input, one image: input `[c, h, w]` -> columns
/// `[c*kh*kw, oh*ow]`.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    img: &[f32],
    col: &mut [f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, kh, stride, pad);
    let ow = conv_out(w, kw, stride, pad);
    debug_assert_eq!(img.len(), c * h * w);
    debug_assert_eq!(col.len(), c * kh * kw * oh * ow);
    let mut row = 0usize;
    for ch in 0..c {
        let plane = &img[ch * h * w..(ch + 1) * h * w];
        for ky in 0..kh {
            for kx in 0..kw {
                let dst = &mut col[row * oh * ow..(row + 1) * oh * ow];
                let mut idx = 0usize;
                for oy in 0..oh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        dst[idx..idx + ow].fill(0.0);
                        idx += ow;
                        continue;
                    }
                    let src_row = &plane[iy as usize * w..(iy as usize + 1) * w];
                    for ox in 0..ow {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        dst[idx] = if ix < 0 || ix >= w as isize {
                            0.0
                        } else {
                            src_row[ix as usize]
                        };
                        idx += 1;
                    }
                }
                row += 1;
            }
        }
    }
}

/// col2im: scatter-add columns `[c*kh*kw, oh*ow]` back to image `[c,h,w]`.
#[allow(clippy::too_many_arguments)]
pub fn col2im(
    col: &[f32],
    img: &mut [f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, kh, stride, pad);
    let ow = conv_out(w, kw, stride, pad);
    img.fill(0.0);
    let mut row = 0usize;
    for ch in 0..c {
        for ky in 0..kh {
            for kx in 0..kw {
                let src = &col[row * oh * ow..(row + 1) * oh * ow];
                let mut idx = 0usize;
                for oy in 0..oh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        idx += ow;
                        continue;
                    }
                    for ox in 0..ow {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix >= 0 && ix < w as isize {
                            img[ch * h * w + iy as usize * w + ix as usize] += src[idx];
                        }
                        idx += 1;
                    }
                }
                row += 1;
            }
        }
    }
}

thread_local! {
    /// Per-thread im2col scratch for the image-parallel conv path.
    static CONV_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// NCHW convolution forward over a whole batch:
/// `(x[n,c,h,w], w[f,c,k,k], bias[f]) -> y[n,f,oh,ow]`, parallelized over
/// images (each image runs im2col + GEMM + bias into its own output
/// slice, with per-thread column scratch).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    y: &mut [f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    num_filter: usize,
    kernel: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, kernel, stride, pad);
    let ow = conv_out(w, kernel, stride, pad);
    let ckk = c * kernel * kernel;
    let spatial = oh * ow;
    debug_assert_eq!(x.len(), n * c * h * w);
    debug_assert_eq!(wt.len(), num_filter * ckk);
    debug_assert_eq!(bias.len(), num_filter);
    debug_assert_eq!(y.len(), n * num_filter * spatial);
    let flops = 2.0 * (n * num_filter * spatial) as f64 * ckk as f64;
    let prof = SpanTimer::start();
    let yp = SendMut::new(y);
    parallel_for_cost(n, 1, flops, |imgs| {
        CONV_SCRATCH.with(|sc| {
            let cols = &mut *sc.borrow_mut();
            cols.resize(ckk * spatial, 0.0);
            for img in imgs {
                im2col(
                    &x[img * c * h * w..(img + 1) * c * h * w],
                    cols,
                    c,
                    h,
                    w,
                    kernel,
                    kernel,
                    stride,
                    pad,
                );
                let y_img = unsafe { yp.slice(img * num_filter * spatial, num_filter * spatial) };
                gemm(wt, cols, y_img, num_filter, ckk, spatial, 0.0);
                for f in 0..num_filter {
                    let row = &mut y_img[f * spatial..(f + 1) * spatial];
                    let bf = bias[f];
                    for v in row.iter_mut() {
                        *v += bf;
                    }
                }
            }
        });
    });
    prof.finish(Category::Kernel, "kernel.conv2d_fwd", 0, flops as u64, 0);
}

/// NCHW convolution forward with a fused epilogue: after each image's
/// im2col + GEMM, the bias and the absorbed elementwise chain run over
/// that image's `[num_filter, oh*ow]` output slice while it is still
/// cache-hot (instead of separate full-tensor sweeps per absorbed op).
/// Bitwise identical to `conv2d_forward` followed by the unfused
/// elementwise kernels for any thread count (see [`Epilogue`]).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward_ep(
    x: &[f32],
    wt: &[f32],
    bias: &[f32],
    y: &mut [f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    num_filter: usize,
    kernel: usize,
    stride: usize,
    pad: usize,
    steps: &[EpStep],
) {
    let oh = conv_out(h, kernel, stride, pad);
    let ow = conv_out(w, kernel, stride, pad);
    let ckk = c * kernel * kernel;
    let spatial = oh * ow;
    debug_assert_eq!(x.len(), n * c * h * w);
    debug_assert_eq!(wt.len(), num_filter * ckk);
    debug_assert_eq!(bias.len(), num_filter);
    debug_assert_eq!(y.len(), n * num_filter * spatial);
    let flops = 2.0 * (n * num_filter * spatial) as f64 * ckk as f64;
    let prof = SpanTimer::start();
    // Per-image the output slice is a [num_filter, spatial] matrix with
    // a per-row (per-filter) bias; Binary operands index into the full
    // [n, num_filter, oh, ow] tensor via the image's base offset.
    let ep = Epilogue { bias: Some(bias), bias_per_row: true, steps };
    let yp = SendMut::new(y);
    parallel_for_cost(n, 1, flops, |imgs| {
        CONV_SCRATCH.with(|sc| {
            let cols = &mut *sc.borrow_mut();
            cols.resize(ckk * spatial, 0.0);
            for img in imgs {
                im2col(
                    &x[img * c * h * w..(img + 1) * c * h * w],
                    cols,
                    c,
                    h,
                    w,
                    kernel,
                    kernel,
                    stride,
                    pad,
                );
                let y_img = unsafe { yp.slice(img * num_filter * spatial, num_filter * spatial) };
                gemm(wt, cols, y_img, num_filter, ckk, spatial, 0.0);
                ep.apply_block(
                    y_img,
                    0,
                    num_filter,
                    0,
                    spatial,
                    spatial,
                    img * num_filter * spatial,
                );
            }
        });
    });
    prof.finish(Category::Kernel, "kernel.conv2d_fwd_ep", 0, flops as u64, 0);
}

/// NCHW convolution backward: `(dy, x, w) -> (dx, dw, db)`.
///
/// The image loop is serial because `dw`/`db` accumulate across images;
/// the heavy inner GEMMs recruit the intra-op pool themselves (they are
/// not nested inside a parallel region here).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward(
    dy: &[f32],
    x: &[f32],
    wt: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
    cols: &mut [f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    num_filter: usize,
    kernel: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, kernel, stride, pad);
    let ow = conv_out(w, kernel, stride, pad);
    let ckk = c * kernel * kernel;
    let spatial = oh * ow;
    let prof = SpanTimer::start();
    dw.fill(0.0);
    db.fill(0.0);
    for img in 0..n {
        let dy_img = &dy[img * num_filter * spatial..(img + 1) * num_filter * spatial];
        // dw += dy_img @ cols^T  (cols from x)
        im2col(
            &x[img * c * h * w..(img + 1) * c * h * w],
            cols,
            c,
            h,
            w,
            kernel,
            kernel,
            stride,
            pad,
        );
        gemm_nt(dy_img, cols, dw, num_filter, spatial, ckk, 1.0);
        // db += rowsum over spatial
        for ff in 0..num_filter {
            let mut s = 0.0;
            for v in &dy_img[ff * spatial..(ff + 1) * spatial] {
                s += v;
            }
            db[ff] += s;
        }
        // dcols = w^T @ dy_img ; dx_img = col2im(dcols)
        gemm_tn(wt, dy_img, cols, ckk, num_filter, spatial, 0.0);
        col2im(
            cols,
            &mut dx[img * c * h * w..(img + 1) * c * h * w],
            c,
            h,
            w,
            kernel,
            kernel,
            stride,
            pad,
        );
    }
    let flops = 4.0 * (n * num_filter * spatial) as f64 * ckk as f64;
    prof.finish(Category::Kernel, "kernel.conv2d_bwd", 0, flops as u64, 0);
}

// ---------------------------------------------------------------------
// Pooling
// ---------------------------------------------------------------------

/// Pooling selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    /// max pooling
    Max,
    /// average pooling
    Avg,
}

/// Pooling forward for one NCHW batch. `argmax` (same size as output)
/// records winning input indices for max-pool backward; ignored for avg.
/// Parallelized over the `n*c` planes (each plane's output and argmax
/// slices are disjoint).
#[allow(clippy::too_many_arguments)]
pub fn pool_forward(
    kind: PoolKind,
    x: &[f32],
    y: &mut [f32],
    argmax: &mut [f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, k, stride, pad);
    let ow = conv_out(w, k, stride, pad);
    debug_assert_eq!(y.len(), n * c * oh * ow);
    if matches!(kind, PoolKind::Max) {
        debug_assert_eq!(argmax.len(), n * c * oh * ow);
    }
    let planes = n * c;
    let yp = SendMut::new(y);
    let amp = SendMut::new(argmax);
    let cost = (planes * oh * ow * k * k) as f64;
    parallel_for_cost(planes, 1, cost, |ps| {
        for p in ps {
            let plane = &x[p * h * w..(p + 1) * h * w];
            let yo = unsafe { yp.slice(p * oh * ow, oh * ow) };
            // Only materialize the argmax slice for max-pooling: avg-pool
            // callers may legitimately pass an empty buffer (the doc says
            // it is ignored), and a zero-capacity `&mut` reborrow at a
            // nonzero offset would be UB.
            let mut am = match kind {
                PoolKind::Max => Some(unsafe { amp.slice(p * oh * ow, oh * ow) }),
                PoolKind::Avg => None,
            };
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0usize;
                    let mut sum = 0.0f32;
                    let mut count = 0usize;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let idx = iy as usize * w + ix as usize;
                            let v = plane[idx];
                            if v > best {
                                best = v;
                                best_idx = idx;
                            }
                            sum += v;
                            count += 1;
                        }
                    }
                    let o = oy * ow + ox;
                    match &mut am {
                        Some(am) => {
                            yo[o] = best;
                            am[o] = best_idx as f32;
                        }
                        None => {
                            yo[o] = if count > 0 { sum / count as f32 } else { 0.0 };
                        }
                    }
                }
            }
        }
    });
}

/// Pooling backward, parallelized over planes (each plane zeroes and
/// scatters into its own `dx` slice).
#[allow(clippy::too_many_arguments)]
pub fn pool_backward(
    kind: PoolKind,
    dy: &[f32],
    argmax: &[f32],
    dx: &mut [f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
) {
    let oh = conv_out(h, k, stride, pad);
    let ow = conv_out(w, k, stride, pad);
    let planes = n * c;
    let dxp = SendMut::new(dx);
    let cost = (planes * oh * ow * k * k) as f64;
    parallel_for_cost(planes, 1, cost, |ps| {
        for p in ps {
            let dxo = unsafe { dxp.slice(p * h * w, h * w) };
            dxo.fill(0.0);
            let out_base = p * oh * ow;
            for oy in 0..oh {
                for ox in 0..ow {
                    let o = out_base + oy * ow + ox;
                    match kind {
                        PoolKind::Max => {
                            dxo[argmax[o] as usize] += dy[o];
                        }
                        PoolKind::Avg => {
                            // distribute evenly over the valid window
                            let mut cells = Vec::with_capacity(k * k);
                            for ky in 0..k {
                                let iy = (oy * stride + ky) as isize - pad as isize;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                for kx in 0..k {
                                    let ix = (ox * stride + kx) as isize - pad as isize;
                                    if ix >= 0 && ix < w as isize {
                                        cells.push(iy as usize * w + ix as usize);
                                    }
                                }
                            }
                            if !cells.is_empty() {
                                let g = dy[o] / cells.len() as f32;
                                for idx in cells {
                                    dxo[idx] += g;
                                }
                            }
                        }
                    }
                }
            }
        }
    });
}

// ---------------------------------------------------------------------
// BatchNorm
// ---------------------------------------------------------------------

/// BatchNorm forward (training mode) over NCHW, per-channel statistics.
/// Writes normalized output plus per-channel `save_mean` / `save_invstd`
/// needed by backward.  Parallelized over channels: each channel's
/// statistics and output stripes are computed serially by one chunk, so
/// the reduction order (and thus the bits) never depends on thread count.
#[allow(clippy::too_many_arguments)]
pub fn batchnorm_forward(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    y: &mut [f32],
    save_mean: &mut [f32],
    save_invstd: &mut [f32],
    n: usize,
    c: usize,
    spatial: usize,
    eps: f32,
) {
    let count = (n * spatial) as f32;
    let yp = SendMut::new(y);
    let smp = SendMut::new(save_mean);
    let sip = SendMut::new(save_invstd);
    let cost = 5.0 * (n * c * spatial) as f64;
    parallel_for_cost(c, 1, cost, |chs| {
        for ch in chs {
            let mut mean = 0.0f32;
            for img in 0..n {
                let base = (img * c + ch) * spatial;
                for s in 0..spatial {
                    mean += x[base + s];
                }
            }
            mean /= count;
            let mut var = 0.0f32;
            for img in 0..n {
                let base = (img * c + ch) * spatial;
                for s in 0..spatial {
                    let d = x[base + s] - mean;
                    var += d * d;
                }
            }
            var /= count;
            let invstd = 1.0 / (var + eps).sqrt();
            unsafe {
                smp.slice(ch, 1)[0] = mean;
                sip.slice(ch, 1)[0] = invstd;
            }
            let (g, b) = (gamma[ch], beta[ch]);
            for img in 0..n {
                let base = (img * c + ch) * spatial;
                let yr = unsafe { yp.slice(base, spatial) };
                for s in 0..spatial {
                    yr[s] = (x[base + s] - mean) * invstd * g + b;
                }
            }
        }
    });
}

/// BatchNorm backward. Returns gradients for x, gamma, beta.
/// Channel-parallel like the forward pass.
#[allow(clippy::too_many_arguments)]
pub fn batchnorm_backward(
    x: &[f32],
    dy: &[f32],
    gamma: &[f32],
    save_mean: &[f32],
    save_invstd: &[f32],
    dx: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    n: usize,
    c: usize,
    spatial: usize,
) {
    let count = (n * spatial) as f32;
    let dxp = SendMut::new(dx);
    let dgp = SendMut::new(dgamma);
    let dbp = SendMut::new(dbeta);
    let cost = 8.0 * (n * c * spatial) as f64;
    parallel_for_cost(c, 1, cost, |chs| {
        for ch in chs {
            let mean = save_mean[ch];
            let invstd = save_invstd[ch];
            let mut sum_dy = 0.0f32;
            let mut sum_dy_xhat = 0.0f32;
            for img in 0..n {
                let base = (img * c + ch) * spatial;
                for s in 0..spatial {
                    let xhat = (x[base + s] - mean) * invstd;
                    sum_dy += dy[base + s];
                    sum_dy_xhat += dy[base + s] * xhat;
                }
            }
            unsafe {
                dgp.slice(ch, 1)[0] = sum_dy_xhat;
                dbp.slice(ch, 1)[0] = sum_dy;
            }
            let g = gamma[ch];
            for img in 0..n {
                let base = (img * c + ch) * spatial;
                let dxr = unsafe { dxp.slice(base, spatial) };
                for s in 0..spatial {
                    let xhat = (x[base + s] - mean) * invstd;
                    dxr[s] =
                        g * invstd * (dy[base + s] - sum_dy / count - xhat * sum_dy_xhat / count);
                }
            }
        }
    });
}

/// Row-wise argmax of `[m,n]` into `out[m]`.
pub fn argmax_rows(x: &[f32], out: &mut [f32], m: usize, n: usize) {
    for i in 0..m {
        let row = &x[i * n..(i + 1) * n];
        let mut best = 0usize;
        for j in 1..n {
            if row[j] > row[best] {
                best = j;
            }
        }
        out[i] = best as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::with_intra_budget;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = crate::util::Rng::seed_from_u64(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (8, 8, 8), (13, 7, 17), (65, 70, 65)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let mut c = vec![0.0; m * n];
            gemm(&a, &b, &mut c, m, k, n, 0.0);
            let want = naive_gemm(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn gemm_nt_tn_match_transposed_naive() {
        let mut rng = crate::util::Rng::seed_from_u64(2);
        for &(m, k, n) in &[(5, 7, 4), (64, 65, 66)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            // b_t is [n,k]
            let mut b_t = vec![0.0; n * k];
            for p in 0..k {
                for j in 0..n {
                    b_t[j * k + p] = b[p * n + j];
                }
            }
            let mut c1 = vec![0.0; m * n];
            gemm_nt(&a, &b_t, &mut c1, m, k, n, 0.0);
            let want = naive_gemm(&a, &b, m, k, n);
            for (x, y) in c1.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3);
            }
            // a_t is [k,m]
            let mut a_t = vec![0.0; k * m];
            for i in 0..m {
                for p in 0..k {
                    a_t[p * m + i] = a[i * k + p];
                }
            }
            let mut c2 = vec![0.0; m * n];
            gemm_tn(&a_t, &b, &mut c2, m, k, n, 0.0);
            for (x, y) in c2.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn gemm_beta_accumulates() {
        let a = [1.0, 0.0, 0.0, 1.0]; // identity 2x2
        let b = [1.0, 2.0, 3.0, 4.0];
        let mut c = [10.0, 10.0, 10.0, 10.0];
        gemm(&a, &b, &mut c, 2, 2, 2, 1.0);
        assert_eq!(c, [11.0, 12.0, 13.0, 14.0]);
    }

    /// Blocked/parallel GEMM must agree with the reference oracle across
    /// transpose variants, odd shapes, and beta values (satellite task:
    /// property coverage; the exhaustive sweep lives in
    /// tests/properties.rs).
    #[test]
    fn blocked_gemm_matches_reference_oracle() {
        let mut rng = crate::util::Rng::seed_from_u64(9);
        for &(m, k, n) in &[(9, 65, 64), (64, 9, 65), (65, 64, 7), (128, 300, 65)] {
            for beta in [0.0f32, 1.0, 0.5] {
                let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
                let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
                let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
                let mut want = c0.clone();
                gemm_reference(&a, &b, &mut want, m, k, n, beta, false, false);
                let mut got = c0.clone();
                gemm(&a, &b, &mut got, m, k, n, beta);
                for (g, w) in got.iter().zip(&want) {
                    let rel = (g - w).abs() / w.abs().max(1.0);
                    assert!(rel < 1e-4, "m={m} k={k} n={n} beta={beta}: {g} vs {w}");
                }
            }
        }
    }

    /// Same seed, different intra-op thread budgets: bitwise-equal output.
    #[test]
    fn gemm_bitwise_deterministic_across_thread_counts() {
        let (m, k, n) = (130, 70, 96);
        let mut rng = crate::util::Rng::seed_from_u64(11);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let run = |budget: usize| {
            with_intra_budget(budget, || {
                let mut c = vec![0.0; m * n];
                gemm(&a, &b, &mut c, m, k, n, 0.0);
                c
            })
        };
        let serial = run(1);
        for budget in [2, 3, 4, 8] {
            let par = run(budget);
            assert!(
                serial.iter().zip(&par).all(|(s, p)| s.to_bits() == p.to_bits()),
                "budget {budget} changed bits"
            );
        }
    }

    #[test]
    fn gemm_ikj_matches_blocked() {
        let (m, k, n) = (33, 47, 29);
        let mut rng = crate::util::Rng::seed_from_u64(12);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut c1 = vec![0.0; m * n];
        gemm_ikj(&a, &b, &mut c1, m, k, n, 0.0);
        let mut c2 = vec![0.0; m * n];
        gemm(&a, &b, &mut c2, m, k, n, 0.0);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn conv2d_forward_matches_serial_composition() {
        // conv2d_forward (possibly image-parallel) vs im2col+gemm by hand.
        let (n, c, h, w, f, k, s, p) = (3, 2, 8, 8, 4, 3, 1, 1);
        let (oh, ow) = (conv_out(h, k, s, p), conv_out(w, k, s, p));
        let mut rng = crate::util::Rng::seed_from_u64(13);
        let x: Vec<f32> = (0..n * c * h * w).map(|_| rng.normal()).collect();
        let wt: Vec<f32> = (0..f * c * k * k).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..f).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; n * f * oh * ow];
        conv2d_forward(&x, &wt, &bias, &mut y, n, c, h, w, f, k, s, p);
        let ckk = c * k * k;
        let spatial = oh * ow;
        let mut cols = vec![0.0; ckk * spatial];
        for img in 0..n {
            im2col(&x[img * c * h * w..(img + 1) * c * h * w], &mut cols, c, h, w, k, k, s, p);
            let mut want = vec![0.0; f * spatial];
            gemm_reference(&wt, &cols, &mut want, f, ckk, spatial, 0.0, false, false);
            for ff in 0..f {
                for sp in 0..spatial {
                    let got = y[img * f * spatial + ff * spatial + sp];
                    let w0 = want[ff * spatial + sp] + bias[ff];
                    assert!((got - w0).abs() < 1e-3, "img={img} f={ff} sp={sp}");
                }
            }
        }
    }

    /// Fused GEMM epilogue vs the unfused kernel composition: bitwise
    /// equal across the small-path gate, the blocked path, and every
    /// thread budget (the epilogue-fusion losslessness contract).
    #[test]
    fn gemm_nt_ep_bitwise_matches_unfused_composition() {
        let mut rng = crate::util::Rng::seed_from_u64(21);
        // (7,5,9) takes the small row-chunk path, (130,70,96) the
        // blocked path — both must honour the contract.
        for &(m, k, n) in &[(7usize, 5usize, 9usize), (130, 70, 96)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let res: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            for kind in [ActKind::Relu, ActKind::Tanh, ActKind::Sigmoid] {
                // Unfused: gemm_nt, bias_add, activation, +0.5, * residual.
                let unfused = with_intra_budget(1, || {
                    let mut c = vec![0.0; m * n];
                    gemm_nt(&a, &b, &mut c, m, k, n, 0.0);
                    bias_add(&mut c, &bias, m, n);
                    let mut y = vec![0.0; m * n];
                    act_forward(kind, &c, &mut y);
                    for v in y.iter_mut() {
                        *v += 0.5;
                    }
                    for (v, r) in y.iter_mut().zip(&res) {
                        *v *= r;
                    }
                    y
                });
                let steps = [
                    EpStep::Act(kind),
                    EpStep::AddScalar(0.5),
                    EpStep::Binary(EwBinary::Mul, &res),
                ];
                let ep = Epilogue { bias: Some(&bias), bias_per_row: false, steps: &steps };
                for budget in [1usize, 4, 8] {
                    let fused = with_intra_budget(budget, || {
                        let mut c = vec![0.0; m * n];
                        gemm_nt_ep(&a, &b, &mut c, m, k, n, 0.0, &ep);
                        c
                    });
                    assert!(
                        unfused.iter().zip(&fused).all(|(u, f)| u.to_bits() == f.to_bits()),
                        "m={m} k={k} n={n} kind={kind:?} budget={budget}: bits differ"
                    );
                }
            }
        }
    }

    /// Fused conv epilogue vs conv2d_forward + separate activation:
    /// bitwise equal for every thread budget.
    #[test]
    fn conv2d_forward_ep_bitwise_matches_unfused() {
        let (n, c, h, w, f, k, s, p) = (3, 2, 8, 8, 4, 3, 1, 1);
        let (oh, ow) = (conv_out(h, k, s, p), conv_out(w, k, s, p));
        let mut rng = crate::util::Rng::seed_from_u64(22);
        let x: Vec<f32> = (0..n * c * h * w).map(|_| rng.normal()).collect();
        let wt: Vec<f32> = (0..f * c * k * k).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..f).map(|_| rng.normal()).collect();
        let unfused = with_intra_budget(1, || {
            let mut y0 = vec![0.0; n * f * oh * ow];
            conv2d_forward(&x, &wt, &bias, &mut y0, n, c, h, w, f, k, s, p);
            let mut y = vec![0.0; n * f * oh * ow];
            act_forward(ActKind::Relu, &y0, &mut y);
            y
        });
        let steps = [EpStep::Act(ActKind::Relu)];
        for budget in [1usize, 4] {
            let fused = with_intra_budget(budget, || {
                let mut y = vec![0.0; n * f * oh * ow];
                conv2d_forward_ep(&x, &wt, &bias, &mut y, n, c, h, w, f, k, s, p, &steps);
                y
            });
            assert!(
                unfused.iter().zip(&fused).all(|(u, g)| u.to_bits() == g.to_bits()),
                "budget {budget}: conv epilogue bits differ"
            );
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = [1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let mut y = [0.0; 6];
        softmax_rows(&x, &mut y, 2, 3);
        for i in 0..2 {
            let s: f32 = y[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        // invariant to shift: rows with equal relative offsets equal probs
        assert!((y[0] - y[3]).abs() < 1e-6);
    }

    #[test]
    fn softmax_deterministic_across_thread_counts() {
        let (m, n) = (512, 257);
        let mut rng = crate::util::Rng::seed_from_u64(14);
        let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let run = |budget: usize| {
            with_intra_budget(budget, || {
                let mut y = vec![0.0; m * n];
                softmax_rows(&x, &mut y, m, n);
                y
            })
        };
        let serial = run(1);
        let par = run(4);
        assert!(serial.iter().zip(&par).all(|(s, p)| s.to_bits() == p.to_bits()));
    }

    #[test]
    fn softmax_xent_gradient_check() {
        // numeric gradient of mean CE wrt logits
        let m = 2;
        let n = 4;
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let logits: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let labels = [1.0, 3.0];
        let loss_of = |lg: &[f32]| {
            let mut p = vec![0.0; m * n];
            softmax_rows(lg, &mut p, m, n);
            xent_loss(&p, &labels, m, n)
        };
        let mut probs = vec![0.0; m * n];
        softmax_rows(&logits, &mut probs, m, n);
        let mut grad = vec![0.0; m * n];
        softmax_xent_backward(&probs, &labels, &mut grad, m, n);
        let eps = 1e-3;
        for i in 0..m * n {
            let mut lp = logits.clone();
            lp[i] += eps;
            let mut lm = logits.clone();
            lm[i] -= eps;
            let num = (loss_of(&lp) - loss_of(&lm)) / (2.0 * eps);
            assert!((num - grad[i]).abs() < 1e-3, "i={i}: {num} vs {}", grad[i]);
        }
    }

    #[test]
    fn im2col_col2im_roundtrip_adjoint() {
        // <im2col(x), y> == <x, col2im(y)> (adjoint property)
        let (c, h, w, kh, kw, s, p) = (2, 5, 5, 3, 3, 1, 1);
        let oh = conv_out(h, kh, s, p);
        let ow = conv_out(w, kw, s, p);
        let mut rng = crate::util::Rng::seed_from_u64(6);
        let x: Vec<f32> = (0..c * h * w).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..c * kh * kw * oh * ow).map(|_| rng.normal()).collect();
        let mut col = vec![0.0; c * kh * kw * oh * ow];
        im2col(&x, &mut col, c, h, w, kh, kw, s, p);
        let mut img = vec![0.0; c * h * w];
        col2im(&y, &mut img, c, h, w, kh, kw, s, p);
        let lhs: f32 = col.iter().zip(&y).map(|(a, b)| a * b).sum();
        let rhs: f32 = x.iter().zip(&img).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() / lhs.abs().max(1.0) < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn maxpool_forward_simple() {
        // 1x1x4x4, k=2, s=2
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut y = vec![0.0; 4];
        let mut am = vec![0.0; 4];
        pool_forward(PoolKind::Max, &x, &mut y, &mut am, 1, 1, 4, 4, 2, 2, 0);
        assert_eq!(y, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn avgpool_forward_simple() {
        let x = vec![1.0, 3.0, 5.0, 7.0]; // 1x1x2x2, k=2 s=2
        let mut y = vec![0.0; 1];
        let mut am = vec![0.0; 1];
        pool_forward(PoolKind::Avg, &x, &mut y, &mut am, 1, 1, 2, 2, 2, 2, 0);
        assert_eq!(y, vec![4.0]);
    }

    #[test]
    fn maxpool_backward_routes_gradient() {
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut y = vec![0.0; 4];
        let mut am = vec![0.0; 4];
        pool_forward(PoolKind::Max, &x, &mut y, &mut am, 1, 1, 4, 4, 2, 2, 0);
        let dy = vec![1.0, 2.0, 3.0, 4.0];
        let mut dx = vec![0.0; 16];
        pool_backward(PoolKind::Max, &dy, &am, &mut dx, 1, 1, 4, 4, 2, 2, 0);
        assert_eq!(dx[5], 1.0);
        assert_eq!(dx[7], 2.0);
        assert_eq!(dx[13], 3.0);
        assert_eq!(dx[15], 4.0);
        assert_eq!(dx.iter().sum::<f32>(), 10.0);
    }

    #[test]
    fn batchnorm_normalizes() {
        let (n, c, sp) = (4, 2, 8);
        let mut rng = crate::util::Rng::seed_from_u64(7);
        let x: Vec<f32> = (0..n * c * sp).map(|_| rng.normal_with(3.0, 2.0)).collect();
        let gamma = vec![1.0; c];
        let beta = vec![0.0; c];
        let mut y = vec![0.0; n * c * sp];
        let mut sm = vec![0.0; c];
        let mut si = vec![0.0; c];
        batchnorm_forward(&x, &gamma, &beta, &mut y, &mut sm, &mut si, n, c, sp, 1e-5);
        // per-channel mean ~0, var ~1
        for ch in 0..c {
            let mut mean = 0.0;
            let mut var = 0.0;
            let cnt = (n * sp) as f32;
            for img in 0..n {
                for s in 0..sp {
                    mean += y[(img * c + ch) * sp + s];
                }
            }
            mean /= cnt;
            for img in 0..n {
                for s in 0..sp {
                    let d = y[(img * c + ch) * sp + s] - mean;
                    var += d * d;
                }
            }
            var /= cnt;
            assert!(mean.abs() < 1e-4, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn batchnorm_gradient_check() {
        let (n, c, sp) = (2, 1, 3);
        let mut rng = crate::util::Rng::seed_from_u64(8);
        let x: Vec<f32> = (0..n * c * sp).map(|_| rng.normal()).collect();
        let gamma = vec![1.3; c];
        let beta = vec![0.2; c];
        let dy: Vec<f32> = (0..n * c * sp).map(|_| rng.normal()).collect();
        let fwd = |xx: &[f32]| {
            let mut y = vec![0.0; n * c * sp];
            let mut sm = vec![0.0; c];
            let mut si = vec![0.0; c];
            batchnorm_forward(xx, &gamma, &beta, &mut y, &mut sm, &mut si, n, c, sp, 1e-5);
            y
        };
        let mut sm = vec![0.0; c];
        let mut si = vec![0.0; c];
        let mut y = vec![0.0; n * c * sp];
        batchnorm_forward(&x, &gamma, &beta, &mut y, &mut sm, &mut si, n, c, sp, 1e-5);
        let mut dx = vec![0.0; n * c * sp];
        let mut dg = vec![0.0; c];
        let mut db = vec![0.0; c];
        batchnorm_backward(&x, &dy, &gamma, &sm, &si, &mut dx, &mut dg, &mut db, n, c, sp);
        let eps = 1e-2;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let f = |yy: Vec<f32>| -> f32 { yy.iter().zip(&dy).map(|(a, b)| a * b).sum() };
            let num = (f(fwd(&xp)) - f(fwd(&xm))) / (2.0 * eps);
            assert!((num - dx[i]).abs() < 2e-2, "i={i}: {num} vs {}", dx[i]);
        }
    }

    #[test]
    fn bias_add_and_grad() {
        let mut x = vec![0.0; 6];
        bias_add(&mut x, &[1.0, 2.0, 3.0], 2, 3);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
        let mut db = vec![0.0; 3];
        bias_grad(&x, &mut db, 2, 3, 0.0);
        assert_eq!(db, vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn argmax_rows_works() {
        let x = [0.1, 0.9, 0.0, 5.0, -1.0, 2.0];
        let mut out = [0.0; 2];
        argmax_rows(&x, &mut out, 2, 3);
        assert_eq!(out, [1.0, 0.0]);
    }
}

//! The size-bucketed storage pool (ISSUE 3): recycled `f32` buffers so
//! the steady-state hot loop — a training step, a served batch — does
//! zero heap allocation after warmup.
//!
//! Once a graph is bound, the set of buffer sizes the hot loop touches
//! is *fixed*: plan storage blocks, workspace scratch, serve staging
//! buffers and imperative-op results all recur with the exact same
//! lengths every step.  The pool therefore shelves freed buffers by
//! exact element count (`HashMap<len, Vec<buf>>`): an `acquire` of a
//! previously-seen size pops a recycled buffer (a *hit*, no malloc, and
//! for [`StoragePool::acquire_uninit`] no memset either), an unseen size
//! falls through to the allocator (a *miss*).  Exact-size bucketing also
//! keeps `Storage::len()` equal to the array size, so whole-buffer reads
//! (`NDArray::to_vec`) never see pool slack.
//!
//! [`Storage`](super::Storage) returns its buffer here on drop, which is
//! what closes the recycling loop: executor temporaries die at executor
//! drop, serve staging [`Lease`]s die per batch, imperative-op results
//! die when their `NDArray` goes out of scope — all of them feed the
//! next step's acquires.
//!
//! Caps (`max_bytes` process-wide, `max_per_size` per shelf) bound the
//! retained set; over-cap releases are dropped to the allocator and
//! counted as *evictions*.  The `PALLAS_STORAGE_POOL` knob (`0` / `off`
//! / `false` / `no`) disables recycling entirely: every acquire is a
//! fresh allocation and every release a plain free, which is the
//! baseline the `engine_micro` bench compares against.
//!
//! All counters are monotonic atomics; [`StoragePool::stats`] snapshots
//! them.  Tests assert steady-state "zero allocations per step" through
//! the miss counter: after warmup, a training step or a served batch
//! must not add a single miss.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Snapshot of pool counters (monotonic since process start, except the
/// `pooled_*` gauges which describe the current shelf contents).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Acquires served from a shelf (no heap allocation).
    pub hits: u64,
    /// Acquires that fell through to the allocator.
    pub misses: u64,
    /// Buffers offered back to the pool.
    pub releases: u64,
    /// Releases dropped because a cap was exceeded (or the pool is
    /// disabled and the buffer was freed).
    pub evictions: u64,
    /// Buffers currently shelved.
    pub pooled_buffers: u64,
    /// Bytes currently shelved.
    pub pooled_bytes: u64,
    /// Bytes currently checked out of the pool (acquired, not yet
    /// released) — live buffers, the complement of the `pooled_*` gauges.
    pub live_bytes: u64,
    /// High-water mark of `live_bytes` since process start (or the last
    /// [`StoragePool::reset_peak`]) — the measured counterpart of the
    /// planner's `MemPlan::peak_bytes`.
    pub peak_bytes: u64,
}

struct Shelves {
    by_len: HashMap<usize, Vec<Box<[f32]>>>,
    bytes: usize,
    buffers: usize,
    /// Bytes currently checked out (live) and their high-water mark.
    live_bytes: usize,
    peak_bytes: usize,
    /// Per-size live buffer counts: len -> (current, peak).
    live_by_len: HashMap<usize, (usize, usize)>,
}

/// A recycling allocator for `f32` buffers, bucketed by exact length.
pub struct StoragePool {
    enabled: bool,
    max_bytes: usize,
    max_per_size: usize,
    shelves: Mutex<Shelves>,
    hits: AtomicU64,
    misses: AtomicU64,
    releases: AtomicU64,
    evictions: AtomicU64,
}

impl StoragePool {
    /// A pool with the default caps (512 MiB total, 32 buffers per size).
    pub fn new(enabled: bool) -> Self {
        Self::with_limits(enabled, 512 << 20, 32)
    }

    /// A pool with explicit caps.
    pub fn with_limits(enabled: bool, max_bytes: usize, max_per_size: usize) -> Self {
        StoragePool {
            enabled,
            max_bytes,
            max_per_size,
            shelves: Mutex::new(Shelves {
                by_len: HashMap::new(),
                bytes: 0,
                buffers: 0,
                live_bytes: 0,
                peak_bytes: 0,
                live_by_len: HashMap::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            releases: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Whether recycling is active.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Pop a shelved buffer of exactly `len` elements, counting the
    /// hit/miss either way.  Zero-length acquires are a counter no-op,
    /// mirroring [`StoragePool::release`]: they never heap-allocate, and
    /// the miss counter is the "allocations per step" acceptance metric.
    fn take(&self, len: usize) -> Option<Box<[f32]>> {
        if len == 0 {
            return None;
        }
        let bytes = len * 4;
        let mut sh = self.shelves.lock().unwrap();
        // Live accounting runs on every acquire (hit, miss, or disabled
        // pool): `live_bytes` tracks checked-out buffers, and its
        // high-water mark is the measured peak-memory gauge.
        sh.live_bytes += bytes;
        sh.peak_bytes = sh.peak_bytes.max(sh.live_bytes);
        let e = sh.live_by_len.entry(len).or_insert((0, 0));
        e.0 += 1;
        e.1 = e.1.max(e.0);
        if self.enabled {
            if let Some(buf) = sh.by_len.get_mut(&len).and_then(|v| v.pop()) {
                sh.bytes -= bytes;
                sh.buffers -= 1;
                drop(sh);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(buf);
            }
        }
        drop(sh);
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// A buffer of `len` elements whose contents are **unspecified**: a
    /// recycled buffer keeps whatever its previous owner wrote (never
    /// uninitialized memory — misses allocate zeroed).  For callers whose
    /// first use fully overwrites the buffer.
    pub fn acquire_uninit(&self, len: usize) -> Box<[f32]> {
        self.take(len).unwrap_or_else(|| vec![0.0f32; len].into_boxed_slice())
    }

    /// A buffer of `len` elements filled with `fill`.  On a pool hit the
    /// fill is an explicit memset; on a miss, `fill == 0.0` uses the
    /// allocator's zeroed path.
    pub fn acquire_filled(&self, len: usize, fill: f32) -> Box<[f32]> {
        match self.take(len) {
            Some(mut buf) => {
                buf.fill(fill);
                buf
            }
            None => vec![fill; len].into_boxed_slice(),
        }
    }

    /// Offer a buffer back for recycling.  Dropped (freed) when the pool
    /// is disabled or a cap would be exceeded.
    pub fn release(&self, buf: Box<[f32]>) {
        let len = buf.len();
        if len == 0 {
            return;
        }
        self.releases.fetch_add(1, Ordering::Relaxed);
        let bytes = len * 4;
        let mut sh = self.shelves.lock().unwrap();
        // Saturating: a buffer can be released here without having been
        // acquired here (e.g. constructed from a Vec and handed over).
        sh.live_bytes = sh.live_bytes.saturating_sub(bytes);
        if let Some(e) = sh.live_by_len.get_mut(&len) {
            e.0 = e.0.saturating_sub(1);
        }
        if !self.enabled {
            drop(sh);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let over_bytes = sh.bytes + bytes > self.max_bytes;
        let shelf = sh.by_len.entry(len).or_default();
        if over_bytes || shelf.len() >= self.max_per_size {
            self.evictions.fetch_add(1, Ordering::Relaxed);
            return; // `buf` drops to the allocator
        }
        shelf.push(buf);
        sh.bytes += bytes;
        sh.buffers += 1;
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> PoolStats {
        let (pooled_buffers, pooled_bytes, live_bytes, peak_bytes) = {
            let sh = self.shelves.lock().unwrap();
            (sh.buffers as u64, sh.bytes as u64, sh.live_bytes as u64, sh.peak_bytes as u64)
        };
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            releases: self.releases.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            pooled_buffers,
            pooled_bytes,
            live_bytes,
            peak_bytes,
        }
    }

    /// Reset the byte high-water marks (total and per-size) to the
    /// current live level, so the next window's peak can be measured in
    /// isolation (benches measure one bind+train window at a time).
    pub fn reset_peak(&self) {
        let mut sh = self.shelves.lock().unwrap();
        sh.peak_bytes = sh.live_bytes;
        for e in sh.live_by_len.values_mut() {
            e.1 = e.0;
        }
    }

    /// Per-size high-water marks: `(elements, peak bytes)` for every
    /// buffer size ever acquired, largest first.  Sizes whose peak fell
    /// to zero after a [`StoragePool::reset_peak`] are omitted.
    pub fn peak_by_size(&self) -> Vec<(usize, u64)> {
        let sh = self.shelves.lock().unwrap();
        let mut v: Vec<(usize, u64)> = sh
            .live_by_len
            .iter()
            .filter(|(_, &(_, peak))| peak > 0)
            .map(|(&len, &(_, peak))| (len, (peak * len * 4) as u64))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(b.0.cmp(&a.0)));
        v
    }

    /// Drop every shelved buffer (tests and memory-pressure hooks).
    pub fn clear(&self) {
        let mut sh = self.shelves.lock().unwrap();
        sh.by_len.clear();
        sh.bytes = 0;
        sh.buffers = 0;
    }
}

/// The process-wide pool every [`Storage`](super::Storage) draws from.
/// Recycling is on by default; `PALLAS_STORAGE_POOL=0|off|false|no`
/// disables it.
pub fn global() -> &'static StoragePool {
    static POOL: OnceLock<StoragePool> = OnceLock::new();
    POOL.get_or_init(|| {
        let enabled = match std::env::var("PALLAS_STORAGE_POOL") {
            Ok(v) => {
                !matches!(v.trim().to_ascii_lowercase().as_str(), "0" | "off" | "false" | "no")
            }
            Err(_) => true,
        };
        StoragePool::new(enabled)
    })
}

/// An RAII scratch buffer leased from the [`global`] pool: derefs to
/// `[f32]`, returns to the pool on drop.  The serving scatter path uses
/// one per dispatched batch instead of a fresh `Vec`.
pub struct Lease {
    buf: Option<Box<[f32]>>,
}

impl Lease {
    fn new(buf: Box<[f32]>) -> Self {
        Lease { buf: Some(buf) }
    }
}

impl std::ops::Deref for Lease {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.buf.as_deref().expect("lease alive")
    }
}

impl std::ops::DerefMut for Lease {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.buf.as_deref_mut().expect("lease alive")
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            global().release(buf);
        }
    }
}

/// Lease a zero-filled scratch buffer of `len` elements from the global
/// pool.
pub fn lease_zeroed(len: usize) -> Lease {
    Lease::new(global().acquire_filled(len, 0.0))
}

/// Lease a scratch buffer with unspecified contents (see
/// [`StoragePool::acquire_uninit`]).
pub fn lease_uninit(len: usize) -> Lease {
    Lease::new(global().acquire_uninit(len))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests construct private pools so their counters are immune to
    // whatever the rest of the (parallel) test suite does to the global
    // pool; global-counter assertions live in tests/plan_pool.rs behind
    // a serialization lock.

    #[test]
    fn miss_then_hit_roundtrip() {
        let p = StoragePool::new(true);
        let a = p.acquire_filled(100, 1.5);
        assert_eq!(a.len(), 100);
        assert!(a.iter().all(|&x| x == 1.5));
        assert_eq!(p.stats().misses, 1);
        p.release(a);
        assert_eq!(p.stats().pooled_buffers, 1);
        let b = p.acquire_uninit(100);
        assert_eq!(b.len(), 100);
        let s = p.stats();
        assert_eq!((s.hits, s.misses, s.pooled_buffers), (1, 1, 0));
        // recycled + uninit: previous contents survive (no memset)
        assert!(b.iter().all(|&x| x == 1.5));
    }

    #[test]
    fn filled_acquire_scrubs_recycled_buffer() {
        let p = StoragePool::new(true);
        let mut a = p.acquire_filled(16, 0.0);
        a.fill(9.0);
        p.release(a);
        let b = p.acquire_filled(16, 0.0);
        assert!(b.iter().all(|&x| x == 0.0), "dirty recycled buffer leaked");
    }

    #[test]
    fn exact_size_bucketing_never_cross_serves() {
        let p = StoragePool::new(true);
        p.release(p.acquire_uninit(64));
        // A differently-sized acquire must not get the 64-elem buffer.
        let b = p.acquire_uninit(32);
        assert_eq!(b.len(), 32);
        assert_eq!(p.stats().hits, 0);
        let c = p.acquire_uninit(64);
        assert_eq!(c.len(), 64);
        assert_eq!(p.stats().hits, 1);
    }

    #[test]
    fn per_size_and_byte_caps_evict() {
        let p = StoragePool::with_limits(true, 4 * 10 * 4, 2);
        // per-size cap of 2: hold three live buffers, then free all three
        let held: Vec<_> = (0..3).map(|_| p.acquire_uninit(4)).collect();
        for b in held {
            p.release(b);
        }
        let s = p.stats();
        assert_eq!(s.pooled_buffers, 2);
        assert_eq!(s.evictions, 1);
        // byte cap: 160 bytes total; a 40-elem release (160 B) exceeds
        // what's left after the two 4-elem (32 B) residents.
        p.release(p.acquire_uninit(40));
        assert_eq!(p.stats().evictions, 2);
    }

    #[test]
    fn disabled_pool_always_misses_and_frees() {
        let p = StoragePool::new(false);
        let a = p.acquire_uninit(8);
        p.release(a);
        let _b = p.acquire_uninit(8);
        let s = p.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 2);
        assert_eq!(s.pooled_buffers, 0);
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn zero_len_is_a_counter_no_op() {
        let p = StoragePool::new(true);
        let a = p.acquire_uninit(0);
        assert_eq!(a.len(), 0);
        p.release(a);
        let s = p.stats();
        assert_eq!(s.pooled_buffers, 0);
        // zero-length buffers never heap-allocate: no miss, no release
        assert_eq!((s.hits, s.misses, s.releases), (0, 0, 0));
    }

    #[test]
    fn live_and_peak_bytes_track_checkouts() {
        let p = StoragePool::new(true);
        let a = p.acquire_uninit(100); // 400 B live
        let b = p.acquire_uninit(50); // 600 B live  <- peak
        assert_eq!(p.stats().live_bytes, 600);
        assert_eq!(p.stats().peak_bytes, 600);
        p.release(a);
        assert_eq!(p.stats().live_bytes, 200);
        assert_eq!(p.stats().peak_bytes, 600, "peak is a high-water mark");
        // Re-acquiring the shelved 100-elem buffer counts as live again
        // but does not exceed the old peak.
        let c = p.acquire_uninit(100);
        let s = p.stats();
        assert_eq!((s.live_bytes, s.peak_bytes), (600, 600));
        p.release(b);
        p.release(c);
        assert_eq!(p.stats().live_bytes, 0);
    }

    #[test]
    fn peak_resets_to_current_live() {
        let p = StoragePool::new(true);
        let a = p.acquire_uninit(256);
        p.release(a);
        assert_eq!(p.stats().peak_bytes, 1024);
        p.reset_peak();
        let s = p.stats();
        assert_eq!((s.live_bytes, s.peak_bytes), (0, 0));
        let b = p.acquire_uninit(8);
        assert_eq!(p.stats().peak_bytes, 32);
        p.release(b);
    }

    #[test]
    fn per_size_peaks_report_bytes_largest_first() {
        let p = StoragePool::new(true);
        let a = p.acquire_uninit(10);
        let b = p.acquire_uninit(10);
        let c = p.acquire_uninit(100);
        let peaks = p.peak_by_size();
        assert_eq!(peaks, vec![(100, 400), (10, 80)]);
        p.release(a);
        p.release(b);
        p.release(c);
        p.reset_peak();
        assert!(p.peak_by_size().is_empty(), "reset drops zero-live sizes");
    }

    #[test]
    fn disabled_pool_still_tracks_live_bytes() {
        let p = StoragePool::new(false);
        let a = p.acquire_uninit(16);
        assert_eq!(p.stats().live_bytes, 64);
        assert_eq!(p.stats().peak_bytes, 64);
        p.release(a);
        assert_eq!(p.stats().live_bytes, 0);
    }

    #[test]
    fn foreign_release_saturates_instead_of_underflowing() {
        let p = StoragePool::new(true);
        // A buffer that was never acquired from this pool.
        p.release(vec![0.0f32; 32].into_boxed_slice());
        let s = p.stats();
        assert_eq!(s.live_bytes, 0);
        assert_eq!(s.releases, 1);
    }

    #[test]
    fn clear_empties_shelves() {
        let p = StoragePool::new(true);
        p.release(p.acquire_uninit(8));
        p.release(p.acquire_uninit(16));
        assert_eq!(p.stats().pooled_buffers, 2);
        p.clear();
        let s = p.stats();
        assert_eq!((s.pooled_buffers, s.pooled_bytes), (0, 0));
    }

    #[test]
    fn lease_derefs_and_recycles() {
        // Functional check only (global pool: counters are shared).
        let len = 12345; // unusual size to avoid cross-test interference
        {
            let mut l = lease_zeroed(len);
            assert_eq!(l.len(), len);
            assert!(l.iter().all(|&x| x == 0.0));
            l[0] = 3.0;
        }
        // Dropped lease went back to the shelf: a fresh uninit lease of
        // the same unusual size sees the sentinel (unless an unrelated
        // thread raced us to it, which no other test does at this size).
        let l2 = lease_uninit(len);
        assert_eq!(l2.len(), len);
    }
}

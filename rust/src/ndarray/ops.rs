//! Imperative NDArray operations, all lazily scheduled on the engine.
//!
//! Includes operator-trait sugar (`&a + &b`, `&a * 2.0`) and the in-place
//! mutation ops (`sub_scaled_`, `add_`) that make the paper's imperative
//! parameter update `w -= eta * g` expressible — and schedulable — next to
//! symbolic graph execution.
//!
//! **Engine affinity.**  Every multi-operand op schedules on the
//! *receiver's* engine; operands created on a different engine get no
//! dependency tracking there (their tags are foreign — see
//! [`crate::engine`]).  Keep all arrays of one computation on one engine;
//! mixing engines is a logic error whose writes race.

use std::sync::Arc;

use super::kernels::{self, EwBinary};
use super::NDArray;

impl NDArray {
    fn binary_ew(&self, other: &NDArray, op: EwBinary, name: &'static str) -> NDArray {
        assert_eq!(self.shape(), other.shape(), "{name}: shape mismatch");
        // The kernel writes every output element, so the result draws an
        // unzeroed buffer from the storage pool (no memset on the hot
        // loop) — same for every other fully-overwriting op below.
        let out = NDArray::alloc_uninit_on(self.shape(), self.engine());
        let (sa, sb, so) = (self.storage(), other.storage(), out.storage());
        self.engine().push(
            name,
            vec![self.var(), other.var()],
            vec![out.var()],
            Box::new(move || unsafe {
                kernels::ew_binary(op, sa.slice(), sb.slice(), so.slice_mut());
            }),
        );
        out
    }

    /// Elementwise addition (lazy).
    pub fn add(&self, other: &NDArray) -> NDArray {
        self.binary_ew(other, EwBinary::Add, "ndarray.add")
    }

    /// Elementwise subtraction (lazy).
    pub fn sub(&self, other: &NDArray) -> NDArray {
        self.binary_ew(other, EwBinary::Sub, "ndarray.sub")
    }

    /// Elementwise multiplication (lazy).
    pub fn mul(&self, other: &NDArray) -> NDArray {
        self.binary_ew(other, EwBinary::Mul, "ndarray.mul")
    }

    /// Elementwise division (lazy).
    pub fn div(&self, other: &NDArray) -> NDArray {
        self.binary_ew(other, EwBinary::Div, "ndarray.div")
    }

    fn scalar_map(&self, name: &'static str, f: impl Fn(f32) -> f32 + Send + 'static) -> NDArray {
        let out = NDArray::alloc_uninit_on(self.shape(), self.engine());
        let (sa, so) = (self.storage(), out.storage());
        self.engine().push(
            name,
            vec![self.var()],
            vec![out.var()],
            Box::new(move || unsafe {
                let a = sa.slice();
                let o = so.slice_mut();
                for i in 0..a.len() {
                    o[i] = f(a[i]);
                }
            }),
        );
        out
    }

    /// `self + s` elementwise (lazy).
    pub fn add_scalar(&self, s: f32) -> NDArray {
        self.scalar_map("ndarray.add_scalar", move |x| x + s)
    }

    /// `self * s` elementwise (lazy).
    pub fn mul_scalar(&self, s: f32) -> NDArray {
        self.scalar_map("ndarray.mul_scalar", move |x| x * s)
    }

    /// Matrix multiply `[m,k] @ [k,n]` (lazy).
    pub fn dot(&self, other: &NDArray) -> NDArray {
        assert_eq!(self.shape().len(), 2, "dot: lhs must be 2-d");
        assert_eq!(other.shape().len(), 2, "dot: rhs must be 2-d");
        let (m, k) = (self.shape()[0], self.shape()[1]);
        let (k2, n) = (other.shape()[0], other.shape()[1]);
        assert_eq!(k, k2, "dot: inner dims {k} vs {k2}");
        // beta = 0.0 below: gemm assigns, never reads, the output.
        let out = NDArray::alloc_uninit_on(&[m, n], self.engine());
        let (sa, sb, so) = (self.storage(), other.storage(), out.storage());
        self.engine().push_costed(
            "ndarray.dot",
            vec![self.var(), other.var()],
            vec![out.var()],
            2.0 * m as f64 * k as f64 * n as f64,
            Box::new(move || unsafe {
                kernels::gemm(sa.slice(), sb.slice(), so.slice_mut(), m, k, n, 0.0);
            }),
        );
        out
    }

    /// Row-wise softmax for a 2-d array (lazy).
    pub fn softmax(&self) -> NDArray {
        assert_eq!(self.shape().len(), 2, "softmax: need 2-d");
        let (m, n) = (self.shape()[0], self.shape()[1]);
        let out = NDArray::alloc_uninit_on(self.shape(), self.engine());
        let (sa, so) = (self.storage(), out.storage());
        self.engine().push_costed(
            "ndarray.softmax",
            vec![self.var()],
            vec![out.var()],
            8.0 * (m * n) as f64,
            Box::new(move || unsafe {
                kernels::softmax_rows(sa.slice(), so.slice_mut(), m, n);
            }),
        );
        out
    }

    /// Sum of all elements (synchronous scalar).
    pub fn sum_sync(&self) -> f32 {
        self.wait_to_read();
        unsafe { self.storage().slice().iter().sum() }
    }

    /// Deep copy (lazy).
    pub fn copy(&self) -> NDArray {
        let out = NDArray::alloc_uninit_on(self.shape(), self.engine());
        let (sa, so) = (self.storage(), out.storage());
        self.engine().push(
            "ndarray.copy",
            vec![self.var()],
            vec![out.var()],
            Box::new(move || unsafe {
                so.slice_mut().copy_from_slice(sa.slice());
            }),
        );
        out
    }

    // ---------------------------------------------------------------
    // in-place mutation ops (the engine's write-dependency feature)
    // ---------------------------------------------------------------

    /// `self += other` in place (lazy).
    pub fn add_(&self, other: &NDArray) {
        assert_eq!(self.shape(), other.shape());
        let (sa, sb) = (self.storage(), other.storage());
        self.engine().push(
            "ndarray.add_",
            vec![other.var()],
            vec![self.var()],
            Box::new(move || unsafe {
                kernels::axpy(1.0, sb.slice(), sa.slice_mut());
            }),
        );
    }

    /// `self -= alpha * other` in place (lazy) — the SGD update
    /// `w -= eta * g` from paper §2.2.
    pub fn sub_scaled_(&self, other: &NDArray, alpha: f32) {
        assert_eq!(self.shape(), other.shape());
        let (sa, sb) = (self.storage(), other.storage());
        self.engine().push(
            "ndarray.sub_scaled_",
            vec![other.var()],
            vec![self.var()],
            Box::new(move || unsafe {
                kernels::axpy(-alpha, sb.slice(), sa.slice_mut());
            }),
        );
    }

    /// `self *= s` in place (lazy).
    pub fn mul_scalar_(&self, s: f32) {
        let sa = self.storage();
        self.engine().push(
            "ndarray.mul_scalar_",
            vec![],
            vec![self.var()],
            Box::new(move || unsafe {
                for v in sa.slice_mut().iter_mut() {
                    *v *= s;
                }
            }),
        );
    }

    /// `self[:] = 0` in place (lazy).
    pub fn zero_(&self) {
        let sa = self.storage();
        self.engine().push(
            "ndarray.zero_",
            vec![],
            vec![self.var()],
            Box::new(move || unsafe {
                sa.slice_mut().fill(0.0);
            }),
        );
    }

    /// `self[:] = other` in place (lazy).
    pub fn copy_from_(&self, other: &NDArray) {
        assert_eq!(self.size(), other.size());
        let (sa, sb) = (self.storage(), other.storage());
        self.engine().push(
            "ndarray.copy_from_",
            vec![other.var()],
            vec![self.var()],
            Box::new(move || unsafe {
                sa.slice_mut().copy_from_slice(sb.slice());
            }),
        );
    }
}

// ----------------------------------------------------------------------
// operator sugar
// ----------------------------------------------------------------------

impl std::ops::Add for &NDArray {
    type Output = NDArray;
    fn add(self, rhs: Self) -> NDArray {
        NDArray::add(self, rhs)
    }
}

impl std::ops::Sub for &NDArray {
    type Output = NDArray;
    fn sub(self, rhs: Self) -> NDArray {
        NDArray::sub(self, rhs)
    }
}

impl std::ops::Mul for &NDArray {
    type Output = NDArray;
    fn mul(self, rhs: Self) -> NDArray {
        NDArray::mul(self, rhs)
    }
}

impl std::ops::Div for &NDArray {
    type Output = NDArray;
    fn div(self, rhs: Self) -> NDArray {
        NDArray::div(self, rhs)
    }
}

impl std::ops::Add<f32> for &NDArray {
    type Output = NDArray;
    fn add(self, rhs: f32) -> NDArray {
        self.add_scalar(rhs)
    }
}

impl std::ops::Mul<f32> for &NDArray {
    type Output = NDArray;
    fn mul(self, rhs: f32) -> NDArray {
        self.mul_scalar(rhs)
    }
}

/// Helper for custom user ops: push an arbitrary closure over explicit
/// read/write arrays (mirrors `mxnet.engine.push`).
pub fn push_custom(
    name: &'static str,
    reads: &[&NDArray],
    writes: &[&NDArray],
    f: impl FnOnce(&[Arc<super::Storage>], &[Arc<super::Storage>]) + Send + 'static,
) {
    let engine = if let Some(a) = writes.first() {
        a.engine()
    } else if let Some(a) = reads.first() {
        a.engine()
    } else {
        crate::engine::default_engine()
    };
    let rs: Vec<_> = reads.iter().map(|a| a.storage()).collect();
    let ws: Vec<_> = writes.iter().map(|a| a.storage()).collect();
    let rv: Vec<_> = reads.iter().map(|a| a.var()).collect();
    let wv: Vec<_> = writes.iter().map(|a| a.var()).collect();
    engine.push(name, rv, wv, Box::new(move || f(&rs, &ws)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_chain() {
        let a = NDArray::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = NDArray::ones(&[2, 2]);
        let c = &(&a + &b) * 2.0; // (a+1)*2
        assert_eq!(c.to_vec(), vec![4.0, 6.0, 8.0, 10.0]);
        let d = &c - &a;
        assert_eq!(d.to_vec(), vec![3.0, 4.0, 5.0, 6.0]);
        let e = &c / &b;
        assert_eq!(e.to_vec(), c.to_vec());
        let f = &a * &a;
        assert_eq!(f.to_vec(), vec![1.0, 4.0, 9.0, 16.0]);
    }

    #[test]
    fn dot_matches_manual() {
        let a = NDArray::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = NDArray::from_vec(&[3, 2], vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.dot(&b);
        assert_eq!(c.shape(), &[2, 2]);
        assert_eq!(c.to_vec(), vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn paper_figure3_example() {
        // Figure 3: a = ones((2,3)); print (a*2).asnumpy()
        let a = NDArray::ones(&[2, 3]);
        let b = &a * 2.0;
        assert_eq!(b.to_vec(), vec![2.0; 6]);
    }

    #[test]
    fn sgd_update_in_place() {
        // w -= eta * g, repeated; engine must serialize the mutations.
        let w = NDArray::zeros(&[4]);
        let g = NDArray::ones(&[4]);
        for _ in 0..10 {
            w.sub_scaled_(&g, 0.1);
        }
        let got = w.to_vec();
        for v in got {
            assert!((v + 1.0).abs() < 1e-5, "{v}");
        }
    }

    #[test]
    fn mutation_ordering_with_reads() {
        // read-after-write and write-after-read interleavings stay in
        // program order per the engine contract.
        let a = NDArray::from_vec(&[1], vec![1.0]);
        let b = a.copy(); // b = 1
        a.mul_scalar_(10.0); // a = 10
        let c = a.copy(); // c = 10
        a.add_(&b); // a = 11
        assert_eq!(b.to_vec(), vec![1.0]);
        assert_eq!(c.to_vec(), vec![10.0]);
        assert_eq!(a.to_vec(), vec![11.0]);
    }

    #[test]
    fn zero_and_copy_from() {
        let a = NDArray::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = NDArray::zeros(&[3]);
        b.copy_from_(&a);
        a.zero_();
        assert_eq!(a.to_vec(), vec![0.0; 3]);
        assert_eq!(b.to_vec(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn push_custom_op() {
        let a = NDArray::from_vec(&[2], vec![3.0, 4.0]);
        let out = NDArray::zeros(&[1]);
        push_custom("l2norm", &[&a], &[&out], |rs, ws| unsafe {
            let x = rs[0].slice();
            let o = ws[0].slice_mut();
            o[0] = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        });
        assert_eq!(out.to_vec(), vec![5.0]);
    }

    #[test]
    fn many_parallel_chains_consistent() {
        // Build 8 independent chains; values must all be exact.
        let chains: Vec<NDArray> = (0..8)
            .map(|i| {
                let mut x = NDArray::full(&[16], i as f32);
                for _ in 0..20 {
                    x = &x + 1.0;
                }
                x
            })
            .collect();
        for (i, x) in chains.iter().enumerate() {
            assert_eq!(x.to_vec(), vec![i as f32 + 20.0; 16]);
        }
    }
}
